"""Executor-core refactor guarantees.

(a) The refactored decoder and enc-dec loss paths (thin adapters over
    runtime/executor.py's StageProgram engine) are bitwise-identical to the
    PRE-REFACTOR executors — frozen verbatim below as ``ref_*`` functions —
    on a tiny config.
(b) The plan-bucket compile cache hits on a second same-bucket plan and
    misses on a different bucket.
(c) Bucket-padding chunks (fully masked: seg = -1, targets = -1) contribute
    exactly zero loss and zero gradient.

Distributed cases run in SUBPROCESSES with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
session keeps seeing exactly one CPU device (see conftest.py).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.configs import get_arch
    from repro.models import DecoderLM, EncDecLM, LayerCtx
    from repro.models.layers import rms_norm, swiglu_apply
    from repro.runtime import TrainStepBuilder, make_geometry
    from repro.runtime import sp
    from repro.runtime.pipeline import (pipeline_loss_fn, _make_model,
                                        init_stage_ctx)
    from repro.runtime.sharding import (gather_layer_params,
                                        gather_stage_params,
                                        shard_dim_tree, shard_map_compat,
                                        stage_param_specs, batch_specs)
    from repro.runtime.train_step import prepare_params

    # =====================================================================
    # FROZEN pre-refactor decoder executor (verbatim from the seed's
    # runtime/pipeline.py: its own lax.scan tick loop, ppermute, remat
    # split and CE folding — the reference the refactor must reproduce
    # bitwise).
    # =====================================================================
    def _ref_run_stage_layers(model, geom, stage_params, shard_dims, x, ctx,
                              *, seg, pos, ctx_len, windows, active,
                              model_axis):
        def layer_body(x, per_layer):
            lp, w, act, lctx = per_layer
            lp_full = lp if geom.zero3_mode == "per_step" else \\
                gather_layer_params(lp, shard_dims, model_axis)
            x_new, new_ctx = model.layer_apply(
                lp_full, x, pos=pos, seg=seg, ctx=lctx, ctx_len=ctx_len,
                window=w)
            x_out = jnp.where(act, x_new, x)
            new_ctx = jax.tree.map(
                lambda new, old: jnp.where(act, new, old) if new is not None
                else None, new_ctx, lctx, is_leaf=lambda t: t is None)
            return x_out, new_ctx

        L_s = geom.layers_per_stage
        l_ck = max(0, min(geom.l_ckpt, L_s))

        def split(tree, a, b):
            return jax.tree.map(lambda t: t[a:b], tree)

        ctx_parts = []
        if l_ck > 0:
            body_ck = jax.checkpoint(layer_body, prevent_cse=False)
            x, ctx_a = jax.lax.scan(
                body_ck, x, (split(stage_params, 0, l_ck),
                             windows[:l_ck], active[:l_ck],
                             split(ctx, 0, l_ck)))
            ctx_parts.append(ctx_a)
        if l_ck < L_s:
            x, ctx_b = jax.lax.scan(
                layer_body, x, (split(stage_params, l_ck, L_s),
                                windows[l_ck:], active[l_ck:],
                                split(ctx, l_ck, L_s)))
            ctx_parts.append(ctx_b)
        if len(ctx_parts) == 2:
            new_ctx = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0) if a is not None
                else None, ctx_parts[0], ctx_parts[1],
                is_leaf=lambda t: t is None)
        else:
            new_ctx = ctx_parts[0]
        return x, new_ctx

    def ref_pipeline_loss_fn(cfg, geom, shard_dims, *, pod_axis,
                             data_axis="data", model_axis="model",
                             mode="train"):
        model = _make_model(cfg, geom, model_axis)
        s = cfg.spec
        L_pad = geom.d_p * geom.layers_per_stage
        win_flat = [cfg.layer_window(i) for i in range(s.n_layers)]
        win_flat += [0] * (L_pad - s.n_layers)
        windows_all = jnp.asarray(win_flat, jnp.int32).reshape(
            geom.d_p, geom.layers_per_stage)
        import numpy as _np
        active_all = jnp.asarray(
            (_np.arange(L_pad) < s.n_layers).reshape(geom.d_p,
                                                     geom.layers_per_stage))

        def loss_local(params, batch):
            p_idx = jax.lax.axis_index(data_axis)
            stage_params = jax.tree.map(lambda x: x[0], params["stages"])
            if geom.zero3_mode == "per_step":
                stage_params = gather_stage_params(stage_params, shard_dims,
                                                   model_axis)
            windows = windows_all[p_idx]
            active = active_all[p_idx]
            n, d_p = geom.n_chunks, geom.d_p
            cap_loc = batch["tokens"].shape[-1]
            dt = geom.compute_dtype

            tokens_a = batch["tokens"].reshape(n, cap_loc)
            targets_a = batch["targets"].reshape(n, cap_loc)
            seg_a = batch["seg"].reshape(n, cap_loc)
            pos_a = batch["pos"].reshape(n, cap_loc)
            ctxlen_a = batch["ctx_len"].reshape(n)

            fn_gamma = params["final_norm"]
            if fn_gamma.shape[0] != s.d_model:
                fn_gamma = jax.lax.all_gather(fn_gamma, model_axis, axis=0,
                                              tiled=True)
            head_w = params.get("unembed", params["embed"])

            ctx0 = init_stage_ctx(cfg, geom)
            x0 = jnp.zeros((cap_loc, s.d_model), dt)

            def tick(carry, t):
                x_recv, ctx, acc0_c, acc1_c = carry
                loss_acc = (acc0_c, acc1_c)
                idx = t - p_idx
                valid = (idx >= 0) & (idx < n)
                idxc = jnp.clip(idx, 0, n - 1)
                tokens = tokens_a[idxc]
                seg = jnp.where(valid, seg_a[idxc], -1)
                pos = pos_a[idxc]
                tgt = targets_a[idxc]
                ctx_len = jnp.where(valid, ctxlen_a[idxc], 0)

                x_emb = sp.sharded_embed(params["embed"], tokens,
                                         model_axis, dt)
                if cfg.embed_scale:
                    x_emb = x_emb * jnp.asarray(s.d_model ** 0.5, dt)
                x_in = jnp.where(p_idx == 0, x_emb, x_recv)

                if ctx.ssm_h is not None:
                    hh = jnp.where(ctx_len == 0, 0.0, ctx.ssm_h)
                    ctx = ctx._replace(ssm_h=hh)

                x_out, ctx = _ref_run_stage_layers(
                    model, geom, stage_params, shard_dims, x_in, ctx,
                    seg=seg, pos=pos, ctx_len=ctx_len, windows=windows,
                    active=active, model_axis=model_axis)

                h_last = rms_norm(x_out, fn_gamma, cfg.rms_eps)
                ce_valid = (seg >= 0) & (tgt >= 0) & valid \\
                    & (p_idx == d_p - 1)
                l_sum, n_val = sp.sharded_ce(h_last, head_w,
                                             jnp.maximum(tgt, 0), ce_valid,
                                             model_axis, vocab_true=s.vocab)
                out_acc = (loss_acc[0] + l_sum, loss_acc[1] + n_val)

                if d_p > 1:
                    x_send = jax.lax.ppermute(
                        x_out, data_axis,
                        [(i, i + 1) for i in range(d_p - 1)])
                else:
                    x_send = x_out
                return (x_send, ctx, out_acc[0], out_acc[1]), None

            acc0 = (jnp.float32(0), jnp.float32(0))
            init = (x0, ctx0, acc0[0], acc0[1])
            (xf, ctxf, a0, a1), _ = jax.lax.scan(
                tick, init, jnp.arange(n + d_p - 1))
            loss = jax.lax.psum(a0, data_axis)
            n_val = jax.lax.psum(a1, data_axis)
            return loss, n_val

        return loss_local

    # =====================================================================
    # Shared tiny-decoder fixture.
    # =====================================================================
    def decoder_case(l_ckpt=1, n_chunks=4, pad_chunks=0, cap=32,
                     schedule="gpipe-1f1b", v_stages=1):
        cfg = get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                              n_heads=4, head_dim=16,
                                              vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n = n_chunks + pad_chunks
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, 256, (n_chunks, cap)).astype(np.int32)
        targets = rng.integers(0, 256, (n_chunks, cap)).astype(np.int32)
        seg = np.repeat(np.arange(n_chunks, dtype=np.int32)[:, None], cap, 1)
        pos = np.tile(np.arange(cap, dtype=np.int32), (n_chunks, 1))
        ctx_len = np.zeros((n_chunks,), np.int32)
        def padc(a, fill):
            out = np.full((n, *a.shape[1:]), fill, a.dtype)
            out[:n_chunks] = a
            return out
        batch = {"tokens": padc(tokens, 0), "targets": padc(targets, -1),
                 "seg": padc(seg, -1), "pos": padc(pos, 0),
                 "ctx_len": padc(ctx_len, 0)}
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        geom = make_geometry(cfg, mesh, n_chunks=n, cap=cap, ctx_cap=2 * cap,
                             l_ckpt=l_ckpt, compute_dtype=jnp.float32,
                             schedule=schedule, v_stages=v_stages)
        builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=jnp.float32)
        raw = DecoderLM(cfg).init(jax.random.PRNGKey(7), jnp.float32)
        params = prepare_params(cfg, raw, mesh, jnp.float32,
                                v_stages=v_stages)
        pspecs, _, bspecs = builder.specs(jax.eval_shape(lambda: params))
        shard_dims = shard_dim_tree(params["stages"], 4)
        return cfg, mesh, geom, params, batch, pspecs, bspecs, shard_dims

    def mapped_loss(loss_fn, mesh, pspecs, bspecs):
        return jax.jit(shard_map_compat(
            loss_fn, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(P(), P()), check_vma=False))
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _COMMON + textwrap.dedent(case)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# (a) bitwise equivalence: decoder path
# ---------------------------------------------------------------------------

def test_decoder_matches_prerefactor_bitwise():
    _run("""
        cfg, mesh, geom, params, batch, pspecs, bspecs, sd = decoder_case(
            l_ckpt=1)
        new = mapped_loss(pipeline_loss_fn(cfg, geom, sd, pod_axis=None),
                          mesh, pspecs, bspecs)
        ref = mapped_loss(ref_pipeline_loss_fn(cfg, geom, sd, pod_axis=None),
                          mesh, pspecs, bspecs)
        ln, nn = new(params, batch)
        lr, nr = ref(params, batch)
        assert float(nn) == float(nr), (nn, nr)
        assert np.asarray(ln).tobytes() == np.asarray(lr).tobytes(), \\
            (float(ln), float(lr))

        # gradients agree too (executor transpose == hand-rolled transpose)
        def scalar(fn):
            def s(p):
                l, n = fn(p, batch)
                return l / n
            return s
        gn = jax.grad(scalar(new))(params)
        gr = jax.grad(scalar(ref))(params)
        for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gr)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        print("OK decoder bitwise", float(ln))
    """)


# ---------------------------------------------------------------------------
# (a) bitwise equivalence: enc-dec path
# ---------------------------------------------------------------------------

def test_encdec_matches_prerefactor_bitwise():
    _run("""
        import math
        from repro.kernels.ref import blocked_flash_attention
        from repro.models.attention import attention_block
        from repro.runtime.encdec_pipeline import (
            encdec_batch_struct, encdec_pipeline_loss_fn,
            make_encdec_geometry, prepare_encdec_params)

        # FROZEN pre-refactor enc-dec executor (verbatim from the seed's
        # runtime/encdec_pipeline.py tick loop).
        def ref_encdec_pipeline_loss_fn(cfg, geom, shard_dims, *, pod_axis,
                                        data_axis="data",
                                        model_axis="model"):
            s = cfg.spec
            d_p, d_s = geom.d_p, geom.d_s
            L_ps = geom.layers_per_stage
            enc_st = geom.enc_stages
            dec_st = d_p - enc_st
            dt = geom.compute_dtype
            self_policy = sp.make_allgather_kv_policy(model_axis)
            nc_policy = sp.make_allgather_kv_policy(model_axis)

            import numpy as _np
            act_enc = (_np.arange(enc_st * L_ps) < s.n_encoder_layers)
            act_dec = (_np.arange(dec_st * L_ps) < s.n_layers)
            active_all = jnp.asarray(
                _np.concatenate([act_enc, act_dec]).reshape(d_p, L_ps))
            scale = 1.0 / math.sqrt(s.head_dim)

            def _cross(lp, h, memory, seg_q, seg_mem):
                dtl = h.dtype
                Dh, Hq, Hkv = s.head_dim, s.n_heads, s.n_kv_heads
                q = jnp.einsum("td,dh->th", h, lp["wq"].astype(dtl)
                               ).reshape(-1, Hq, Dh)
                k = jnp.einsum("sd,dh->sh", memory, lp["wk"].astype(dtl)
                               ).reshape(-1, Hkv, Dh)
                v = jnp.einsum("sd,dh->sh", memory, lp["wv"].astype(dtl)
                               ).reshape(-1, Hkv, Dh)
                k = jax.lax.all_gather(k, model_axis, axis=0, tiled=True)
                v = jax.lax.all_gather(v, model_axis, axis=0, tiled=True)
                sm = jax.lax.all_gather(seg_mem, model_axis, axis=0,
                                        tiled=True)
                z_q = jnp.zeros((q.shape[0],), jnp.int32)
                z_k = jnp.zeros((k.shape[0],), jnp.int32)
                out = blocked_flash_attention(q, k, v, seg_q, sm, z_q, z_k,
                                              causal=False, window=0,
                                              scale=scale)
                return jnp.einsum("th,hd->td", out.reshape(h.shape[0], -1),
                                  lp["wo"].astype(dtl))

            def loss_local(params, batch):
                p_idx = jax.lax.axis_index(data_axis)
                stage_params = jax.tree.map(lambda x: x[0],
                                            params["stages"])
                active = active_all[p_idx]
                n = geom.n_chunks
                cap_loc = batch["tokens"].shape[-1]
                cape_loc = batch["frames"].shape[-2]
                is_enc = p_idx < enc_st

                head_w = params["embed"]
                fn_gamma = params["final_norm"]
                if fn_gamma.shape[0] != s.d_model:
                    fn_gamma = jax.lax.all_gather(fn_gamma, model_axis,
                                                  axis=0, tiled=True)
                en_gamma = params["enc_norm"]
                if en_gamma.shape[0] != s.d_model:
                    en_gamma = jax.lax.all_gather(en_gamma, model_axis,
                                                  axis=0, tiled=True)

                kcap = geom.ctx_cap
                ctx0 = LayerCtx(
                    jnp.zeros((L_ps, kcap, s.n_kv_heads, s.head_dim), dt),
                    jnp.zeros((L_ps, kcap, s.n_kv_heads, s.head_dim), dt),
                    None, None)

                def tick(carry, t):
                    h_enc, h_dec, ctx, loss_acc, n_acc = carry
                    idx = t - p_idx
                    valid = (idx >= 0) & (idx < n)
                    idxc = jnp.clip(idx, 0, n - 1)
                    tokens = batch["tokens"][idxc]
                    seg = jnp.where(valid, batch["seg"][idxc], -1)
                    pos = batch["pos"][idxc]
                    tgt = batch["targets"][idxc]
                    ctx_len = jnp.where(valid, batch["ctx_len"][idxc], 0)
                    seg_e = jnp.where(valid, batch["seg_enc"][idxc], -1)
                    pos_e = batch["pos_enc"][idxc]

                    h_enc = jnp.where(p_idx == 0, batch["frames"][idxc],
                                      h_enc)
                    x_emb = sp.sharded_embed(params["embed"], tokens,
                                             model_axis, dt)
                    h_dec = jnp.where(p_idx == enc_st, x_emb, h_dec)
                    h_enc = jnp.where(p_idx == enc_st,
                                      rms_norm(h_enc, en_gamma,
                                               cfg.rms_eps), h_enc)

                    def layer_body(carry2, per_layer):
                        he, hd = carry2
                        lp, act, lctx = per_layer
                        lp = gather_layer_params(lp, shard_dims, model_axis)
                        h1 = rms_norm(he, lp["ln1"], cfg.rms_eps)
                        eo, _, _ = attention_block(
                            cfg, lp["attn"], h1, pos=pos_e, seg=seg_e,
                            ctx_k=None, ctx_v=None, ctx_len=None, window=0,
                            attn_fn=nc_policy, causal=False)
                        he_new = he + eo
                        he_new = he_new + swiglu_apply(
                            lp["mlp"], rms_norm(he_new, lp["ln2"],
                                                cfg.rms_eps))
                        d1 = rms_norm(hd, lp["ln1"], cfg.rms_eps)
                        do, nk, nv = attention_block(
                            cfg, lp["attn"], d1, pos=pos, seg=seg,
                            ctx_k=lctx.k, ctx_v=lctx.v, ctx_len=ctx_len,
                            window=0, attn_fn=self_policy, causal=True)
                        hd_new = hd + do
                        hx = rms_norm(hd_new, lp["ln_x"], cfg.rms_eps)
                        hd_new = hd_new + _cross(lp["cross"], hx, h_enc,
                                                 seg, seg_e)
                        hd_new = hd_new + swiglu_apply(
                            lp["mlp"], rms_norm(hd_new, lp["ln2"],
                                                cfg.rms_eps))
                        he_out = jnp.where(act & is_enc, he_new, he)
                        hd_out = jnp.where(act & (~is_enc), hd_new, hd)
                        new_ctx = LayerCtx(
                            jnp.where(act & (~is_enc), nk, lctx.k),
                            jnp.where(act & (~is_enc), nv, lctx.v),
                            None, None)
                        return (he_out, hd_out), new_ctx

                    (h_enc2, h_dec2), new_ctx = jax.lax.scan(
                        layer_body, (h_enc, h_dec),
                        (stage_params, active, ctx))

                    h_last = rms_norm(h_dec2, fn_gamma, cfg.rms_eps)
                    ce_valid = (seg >= 0) & (tgt >= 0) & valid \\
                        & (p_idx == d_p - 1)
                    l_sum, n_val = sp.sharded_ce(h_last, head_w,
                                                 jnp.maximum(tgt, 0),
                                                 ce_valid, model_axis,
                                                 vocab_true=s.vocab)
                    loss_acc = loss_acc + l_sum
                    n_acc = n_acc + n_val
                    perm = [(i, i + 1) for i in range(d_p - 1)]
                    h_enc_s = jax.lax.ppermute(h_enc2, data_axis, perm)
                    h_dec_s = jax.lax.ppermute(h_dec2, data_axis, perm)
                    return (h_enc_s, h_dec_s, new_ctx, loss_acc, n_acc), None

                he0 = jnp.zeros((cape_loc, s.d_model), dt)
                hd0 = jnp.zeros((cap_loc, s.d_model), dt)
                init = (he0, hd0, ctx0, jnp.float32(0), jnp.float32(0))
                (he, hd, ctxf, loss, n_val), _ = jax.lax.scan(
                    tick, init, jnp.arange(n + d_p - 1))
                loss = jax.lax.psum(loss, data_axis)
                n_val = jax.lax.psum(n_val, data_axis)
                return loss, n_val

            return loss_local

        cfg = get_arch("seamless-m4t-v2").reduced(n_layers=2, d_model=64,
                                                  n_heads=4, head_dim=16,
                                                  vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, cap, cape = 3, 32, 32
        geom = make_encdec_geometry(cfg, mesh, n_chunks=n, cap=cap,
                                    cap_enc=cape, ctx_cap=2 * cap, l_ckpt=0,
                                    compute_dtype=jnp.float32)
        raw = EncDecLM(cfg).init(jax.random.PRNGKey(5), jnp.float32)
        params = prepare_encdec_params(cfg, raw, geom, jnp.float32)
        d_s = 4
        pspecs = {
            "stages": stage_param_specs(
                jax.eval_shape(lambda: params)["stages"], d_s, pod=None),
            "embed": P("model", None),
            "enc_norm": P("model"),
            "final_norm": P("model"),
        }
        shard_dims = shard_dim_tree(params["stages"], d_s)
        bstruct = encdec_batch_struct(geom, cfg, 1)
        bspecs = batch_specs(bstruct, pod=None, model="model")
        rng = np.random.default_rng(2)
        batch = {}
        for k, v in bstruct.items():
            if v.dtype == jnp.int32:
                if k.startswith("seg"):
                    arr = np.zeros(v.shape, np.int32)
                elif k.startswith("pos"):
                    arr = np.tile(np.arange(v.shape[-1], dtype=np.int32),
                                  (*v.shape[:-1], 1))
                elif k == "ctx_len":
                    arr = np.zeros(v.shape, np.int32)
                else:
                    arr = rng.integers(0, 256, v.shape).astype(np.int32)
            else:
                arr = rng.normal(0, 0.5, v.shape).astype(np.float32)
            batch[k] = jnp.asarray(arr)

        new = mapped_loss(
            encdec_pipeline_loss_fn(cfg, geom, shard_dims, pod_axis=None),
            mesh, pspecs, bspecs)
        ref = mapped_loss(
            ref_encdec_pipeline_loss_fn(cfg, geom, shard_dims,
                                        pod_axis=None),
            mesh, pspecs, bspecs)
        ln, nn = new(params, batch)
        lr, nr = ref(params, batch)
        assert float(nn) == float(nr), (nn, nr)
        assert np.asarray(ln).tobytes() == np.asarray(lr).tobytes(), \\
            (float(ln), float(lr))
        print("OK encdec bitwise", float(ln))
    """)


# ---------------------------------------------------------------------------
# (c) bucket-padding chunks contribute exactly zero loss/grad
# ---------------------------------------------------------------------------

def test_bucket_padding_zero_contribution():
    _run("""
        cfg, mesh, geom, params, batch, pspecs, bspecs, sd = decoder_case(
            l_ckpt=0, n_chunks=4, pad_chunks=0)
        cfgp, meshp, geomp, paramsp, batchp, pspecsp, bspecsp, sdp = \\
            decoder_case(l_ckpt=0, n_chunks=4, pad_chunks=4)

        def scalar(fn, b):
            def s(p):
                l, n = fn(p, b)
                return l / jnp.maximum(n, 1.0)
            return s
        f0 = mapped_loss(pipeline_loss_fn(cfg, geom, sd, pod_axis=None),
                         mesh, pspecs, bspecs)
        f1 = mapped_loss(pipeline_loss_fn(cfgp, geomp, sdp, pod_axis=None),
                         meshp, pspecsp, bspecsp)
        l0, n0 = f0(params, batch)
        l1, n1 = f1(paramsp, batchp)
        assert float(n0) == float(n1), (n0, n1)
        assert np.asarray(l0).tobytes() == np.asarray(l1).tobytes(), \\
            (float(l0), float(l1))
        g0 = jax.grad(scalar(f0, batch))(params)
        g1 = jax.grad(scalar(f1, batchp))(paramsp)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK padding", float(l0))
    """)


# ---------------------------------------------------------------------------
# (b) compile cache: hit on a same-bucket plan, miss on a different bucket
# ---------------------------------------------------------------------------

def test_bucket_cache_hit_and_miss():
    from repro.core import ClusterSpec, CostModel, ModelSpec, PlannerConfig, \
        plan_batch
    from repro.runtime.compile_cache import CompileCache

    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab=512)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4))
    pc = PlannerConfig(bucket_rounding=64)
    plan_a = plan_batch(cm, [512, 384, 256, 256], pc)
    plan_b = plan_batch(cm, [512, 384, 256, 256], pc)   # same workload
    plan_c = plan_batch(cm, [8192, 4096, 512, 256], pc)  # different bucket

    d_s = 4
    assert plan_a.bucket_key(d_s) == plan_b.bucket_key(d_s)
    assert plan_a.bucket_key(d_s) != plan_c.bucket_key(d_s)

    builds = []
    cache = CompileCache(name="test")

    def make_build(tag):
        def build():
            builds.append(tag)
            return tag
        return build

    assert cache.get(plan_a.bucket_key(d_s), make_build("a")) == "a"
    assert cache.get(plan_b.bucket_key(d_s), make_build("b")) == "a"  # hit
    assert cache.get(plan_c.bucket_key(d_s), make_build("c")) == "c"  # miss
    assert builds == ["a", "c"]
    assert cache.stats.hits == 1 and cache.stats.misses == 2
    assert cache.stats.hit_rate == pytest.approx(1 / 3)


def test_bucket_key_named_fields():
    """bucket_key() returns a NamedTuple: consumers (launch/train.py,
    benchmarks) access geometry by NAME — positional slices like
    ``key[2:4]`` broke silently when PR 2 reordered the tuple."""
    from repro.core import BucketKey, ClusterSpec, CostModel, ModelSpec, \
        PlannerConfig, plan_batch

    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8,
                  n_kv_heads=4, head_dim=32, d_ff=1024, vocab=512)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4))
    plan = plan_batch(cm, [512, 384, 256, 256],
                      PlannerConfig(bucket_rounding=64))
    key = plan.bucket_key(4)
    assert isinstance(key, BucketKey)
    assert BucketKey._fields == ("schedule", "v_stages", "n_chunks",
                                 "cap", "ctx_cap", "l_ckpt", "ckpt",
                                 "split_bwd", "dtype", "sp_policy",
                                 "d_s_eff")
    # named access agrees with the documented order (and stays a tuple:
    # hashable, comparable, usable as a cache key)
    assert key.schedule == key[0] == plan.schedule
    assert key.v_stages == key[1] == plan.v_stages
    assert key.n_chunks == key[2] and key.cap == key[3]
    assert key.ctx_cap == key[4] and key.l_ckpt == key[5]
    assert key.ckpt == key[6] == f"u{plan.uniform_ckpt()}"
    # the lowering-relevant plan axes added by the auditor PR: split_bwd
    # resolves "auto" through the schedule backend, dtype is a string
    assert isinstance(key.split_bwd, bool)
    assert key.dtype == "bfloat16"
    # the SP axis (PR 8): the planner's (policy, d_s_eff) is part of the
    # compile identity so SP-differing plans never alias executables
    assert key.sp_policy == plan.sp.policy
    assert key.d_s_eff == plan.sp.d_s_eff
    forced = plan.bucket_key(4, split_bwd="on", dtype="float32")
    assert forced.split_bwd is True and forced.dtype == "float32"
    assert forced != key or (key.split_bwd and key.dtype == "float32")
    assert key.n_chunks % 8 == 0 and key.cap % 4 == 0
    assert hash(key) == hash(tuple(key))


def test_cache_eviction_lru():
    from repro.runtime.compile_cache import CompileCache
    cache = CompileCache(name="evict", capacity=2)
    cache.get(1, lambda: "one")
    cache.get(2, lambda: "two")
    cache.get(1, lambda: "one")       # refresh 1 -> 2 becomes LRU
    cache.get(3, lambda: "three")     # evicts 2
    assert cache.stats.evictions == 1
    assert 2 not in cache and 1 in cache and 3 in cache


# ---------------------------------------------------------------------------
# (d) schedule backends on the same fixtures: zero-bubble-h1 (W-grad fused)
#     and interleaved-1f1b at v=1 are bitwise-loss-identical to the default
#     1F1B executor; interleaved at v=2 computes the same model (virtual
#     stages ride the ring in layer order), so loss and grads match too.
# ---------------------------------------------------------------------------

def test_schedule_backends_bitwise_at_v1():
    _run("""
        cfg, mesh, geom, params, batch, pspecs, bspecs, sd = decoder_case(
            l_ckpt=1)
        base = mapped_loss(pipeline_loss_fn(cfg, geom, sd, pod_axis=None),
                           mesh, pspecs, bspecs)
        l0, n0 = base(params, batch)
        for schedule in ("zero-bubble-h1", "interleaved-1f1b"):
            cfg2, mesh2, geom2, params2, batch2, pspecs2, bspecs2, sd2 = \\
                decoder_case(l_ckpt=1, schedule=schedule, v_stages=1)
            fn = mapped_loss(
                pipeline_loss_fn(cfg2, geom2, sd2, pod_axis=None),
                mesh2, pspecs2, bspecs2)
            l1, n1 = fn(params2, batch2)
            assert float(n0) == float(n1), (schedule, n0, n1)
            assert np.asarray(l0).tobytes() == np.asarray(l1).tobytes(), \\
                (schedule, float(l0), float(l1))
        print("OK schedule backends bitwise", float(l0))
    """)


def test_interleaved_v2_matches_v1():
    _run("""
        from repro.runtime.sharding import unstack_stages
        cfg, mesh, geom, params, batch, pspecs, bspecs, sd = decoder_case(
            l_ckpt=1)
        cfg2, mesh2, geom2, params2, batch2, pspecs2, bspecs2, sd2 = \\
            decoder_case(l_ckpt=1, schedule="interleaved-1f1b", v_stages=2)
        f1 = mapped_loss(pipeline_loss_fn(cfg, geom, sd, pod_axis=None),
                         mesh, pspecs, bspecs)
        f2 = mapped_loss(pipeline_loss_fn(cfg2, geom2, sd2, pod_axis=None),
                         mesh2, pspecs2, bspecs2)
        l1, n1 = f1(params, batch)
        l2, n2 = f2(params2, batch2)
        assert float(n1) == float(n2), (n1, n2)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

        def scalar(fn, b):
            def s(p):
                l, n = fn(p, b)
                return l / n
            return s
        g1 = jax.grad(scalar(f1, batch))(params)
        g2 = jax.grad(scalar(f2, batch2))(params2)
        # stage grads live in different stackings; compare unstacked
        u1 = unstack_stages(g1["stages"], cfg.spec.n_layers)
        u2 = unstack_stages(g2["stages"], cfg.spec.n_layers, v=2)
        for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        for name in ("embed", "final_norm"):
            np.testing.assert_allclose(np.asarray(g1[name]),
                                       np.asarray(g2[name]),
                                       rtol=1e-6, atol=1e-7)
        print("OK interleaved v2", float(l2))
    """)
