"""Split-backward / double-buffered hand-off parity harness.

The zero-bubble refactor gives the executor two new compiled shapes
(``runtime/executor.py``):

* **B/W backward split** (``split_backward_stage`` + the W-drain scan):
  the critical-path tick computes only activation cotangents and stashes
  the boundary residuals; dedicated drain ticks recompute the weight
  grads during cooldown — ZB-H1's W-grad fill, now present in the HLO.
* **Double-buffered hand-off** (``overlap_handoff``): the stream
  ppermute is issued before the accumulator fold so XLA's async
  collectives + latency-hiding scheduler can overlap them.

Both are pure scheduling transforms — this suite pins that they never
change the math:

* losses are **bitwise identical** between the fused autodiff transpose
  and the split path, for every schedule backend (the split is forced on
  via ``make_geometry(split_bwd=True)`` even for fused-schedule names);
* gradients agree at the repo grad-parity standard (rtol=1e-6 /
  atol=1e-7 — weight grads are *recomputed* in the drain, so fusion
  differs in final-ULP noise, same as remat);
* ``overlap_handoff`` on/off is bitwise identical, loss AND grads (the
  fold consumes the pre-permute buffer either way);
* the split composes with the traced per-(stage, chunk) remat table.

Runs in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest session keeps seeing one CPU device (see conftest.py).
"""

import os
import subprocess
import sys
import textwrap

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.configs import get_arch
    from repro.models import DecoderLM
    from repro.runtime import TrainStepBuilder, make_geometry
    from repro.runtime.pipeline import pipeline_loss_fn
    from repro.runtime.sharding import shard_dim_tree, shard_map_compat
    from repro.runtime.train_step import prepare_params

    SCHEDULES = [("gpipe-1f1b", 1), ("interleaved-1f1b", 2),
                 ("zero-bubble-h1", 1)]

    def decoder_case(l_ckpt=0, ckpt_table=None, schedule="gpipe-1f1b",
                     v_stages=1, split_bwd=None, overlap_handoff=True):
        cfg = get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                              n_heads=4, head_dim=16,
                                              vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, cap = 4, 32
        rng = np.random.default_rng(0)
        batch = {
            "tokens": rng.integers(0, 256, (n, cap)).astype(np.int32),
            "targets": rng.integers(0, 256, (n, cap)).astype(np.int32),
            "seg": np.repeat(np.arange(n, dtype=np.int32)[:, None], cap, 1),
            "pos": np.tile(np.arange(cap, dtype=np.int32), (n, 1)),
            "ctx_len": np.zeros((n,), np.int32),
        }
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        geom = make_geometry(cfg, mesh, n_chunks=n, cap=cap, ctx_cap=2 * cap,
                             l_ckpt=l_ckpt, compute_dtype=jnp.float32,
                             schedule=schedule, v_stages=v_stages,
                             ckpt_table=ckpt_table, split_bwd=split_bwd,
                             overlap_handoff=overlap_handoff)
        builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=jnp.float32)
        raw = DecoderLM(cfg).init(jax.random.PRNGKey(7), jnp.float32)
        params = prepare_params(cfg, raw, mesh, jnp.float32,
                                v_stages=v_stages)
        pspecs, _, bspecs = builder.specs(jax.eval_shape(lambda: params))
        sd = shard_dim_tree(params["stages"], 4)
        loss = pipeline_loss_fn(cfg, geom, sd, pod_axis=None)
        fn = jax.jit(shard_map_compat(
            loss, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(P(), P()), check_vma=False))
        return fn, params, batch

    def loss_and_grads(fn, params, batch):
        def scalar(p):
            l, n = fn(p, batch)
            return l / n
        l, nv = fn(params, batch)
        g = jax.grad(scalar)(params)
        return (np.asarray(l), float(nv),
                [np.asarray(x) for x in jax.tree.leaves(g)])

    def check_split_parity(fused, split, tag):
        (lf, nf, gf), (ls, ns, gs) = fused, split
        assert nf == ns, (tag, nf, ns)
        assert lf.tobytes() == ls.tobytes(), (tag, float(lf), float(ls))
        for a, b in zip(gf, gs):
            np.testing.assert_allclose(
                a, b, rtol=1e-6, atol=1e-7,
                err_msg=f"{tag}: grads drifted across the B/W split")
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _COMMON + textwrap.dedent(case)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Fused vs split across every schedule backend. split_bwd=True is forced
# even for the fused-schedule names: the split is a property of the
# executor, not of the tick map, and must be correct anywhere.
# ---------------------------------------------------------------------------

def test_split_backward_parity_all_schedules():
    _run("""
        for schedule, v in SCHEDULES:
            fused = loss_and_grads(*decoder_case(
                schedule=schedule, v_stages=v, split_bwd=False))
            split = loss_and_grads(*decoder_case(
                schedule=schedule, v_stages=v, split_bwd=True))
            check_split_parity(fused, split, f"{schedule}-v{v}")
            print("split parity", schedule, v, float(split[0]))
        print("OK split-backward parity")
    """)


# ---------------------------------------------------------------------------
# zero-bubble-h1's default geometry IS the split path (make_geometry
# derives split_bwd from the schedule spec) — and it matches the fused
# 1F1B baseline on the same tick diagonal.
# ---------------------------------------------------------------------------

def test_zero_bubble_default_matches_fused_1f1b():
    _run("""
        from repro.core.schedule import get_schedule
        assert get_schedule("zero-bubble-h1").split_bwd
        fused = loss_and_grads(*decoder_case(schedule="gpipe-1f1b",
                                             split_bwd=False))
        zb = loss_and_grads(*decoder_case(schedule="zero-bubble-h1"))
        check_split_parity(fused, zb, "zb-default-vs-fused-1f1b")
        print("OK zero-bubble default", float(zb[0]))
    """)


# ---------------------------------------------------------------------------
# The split composes with stage-aware remat: traced per-(stage, chunk)
# ckpt tables thread through the split stage body (the drain recomputes
# at l_ckpt=0 regardless — W-grad recompute is its own remat).
# ---------------------------------------------------------------------------

def test_split_backward_composes_with_traced_remat():
    _run("""
        TAB = ((2, 0, 1, 2), (1, 2, 0, 0))
        for kw in (dict(l_ckpt=2), dict(l_ckpt=2, ckpt_table=TAB)):
            fused = loss_and_grads(*decoder_case(split_bwd=False, **kw))
            split = loss_and_grads(*decoder_case(split_bwd=True, **kw))
            check_split_parity(fused, split, f"remat-{kw}")
        print("OK split x remat parity")
    """)


# ---------------------------------------------------------------------------
# Double-buffered hand-off: folding the pre-permute buffer before or
# after the ppermute is issued is the same program — bitwise, loss AND
# grads, with and without the split.
# ---------------------------------------------------------------------------

def test_overlap_handoff_bitwise():
    _run("""
        for split in (False, True):
            lo, no, go = loss_and_grads(*decoder_case(
                split_bwd=split, overlap_handoff=True))
            ls, ns, gs = loss_and_grads(*decoder_case(
                split_bwd=split, overlap_handoff=False))
            assert no == ns
            assert lo.tobytes() == ls.tobytes(), (float(lo), float(ls))
            for a, b in zip(go, gs):
                assert a.tobytes() == b.tobytes(), \\
                    f"hand-off buffering changed the math (split={split})"
        print("OK overlap hand-off bitwise")
    """)


# ---------------------------------------------------------------------------
# Host-side satellites (no subprocess needed).
# ---------------------------------------------------------------------------

def test_configure_latency_hiding_env_handling(monkeypatch):
    from repro.launch.mesh import (LATENCY_HIDING_FLAGS, OPT_OUT_ENV,
                                   configure_latency_hiding)
    monkeypatch.delenv(OPT_OUT_ENV, raising=False)
    monkeypatch.setenv("XLA_FLAGS", "--prior=1")
    assert configure_latency_hiding()
    flags = os.environ["XLA_FLAGS"]
    assert flags.startswith(LATENCY_HIDING_FLAGS)
    assert flags.endswith("--prior=1")
    # idempotent
    assert configure_latency_hiding()
    assert os.environ["XLA_FLAGS"].count(
        "--xla_gpu_enable_latency_hiding_scheduler") == 1
    # opt-outs leave the env untouched
    monkeypatch.setenv("XLA_FLAGS", "--prior=1")
    assert not configure_latency_hiding(enable=False)
    assert os.environ["XLA_FLAGS"] == "--prior=1"
    monkeypatch.setenv(OPT_OUT_ENV, "1")
    assert not configure_latency_hiding()
    assert os.environ["XLA_FLAGS"] == "--prior=1"


def test_production_mesh_validates_device_count():
    import pytest

    from repro.launch.mesh import make_production_mesh

    # the test session runs on far fewer than 256 devices
    with pytest.raises(ValueError, match="256 devices"):
        make_production_mesh()
    with pytest.raises(ValueError, match="512 devices"):
        make_production_mesh(multi_pod=True)


def test_pipeline_bubble_benchmark_meets_acceptance():
    """The committed benchmark geometry honors the acceptance criteria:
    ZB-H1's realized bubble strictly below 1F1B's and within 15% of the
    closed-form model bubble."""
    from benchmarks.paper_figures import pipeline_bubble

    rows = {r["schedule"]: r for r in pipeline_bubble()}
    zb, fb = rows["zero-bubble-h1"], rows["gpipe-1f1b"]
    assert zb["realized_bubble"] < fb["realized_bubble"]
    assert zb["realized_over_model"] <= 1.15
    assert zb["speedup_vs_1f1b"] > 1.0
    # the simulator's free-form W placement must beat (or meet) the
    # lockstep-realized bubble — it is the lower envelope
    assert zb["sim_bubble"] <= zb["realized_bubble"] + 1e-9
