"""Serving-engine guarantees.

Device-level (subprocess, 4 fake CPU devices, 2x2 mesh — see
test_executor_core.py for the pattern):

(a) continuous-batching engine greedy ids == the one-shot serve path
    (whole-prompt prefill + teacher-forced recompute, no KV reuse) at
    k=1, over a staggered multi-request trace — and the engine's
    compile-cache bucket set is CLOSED: a second identical trace pass
    compiles nothing.
(c) speculative k=2 output ids == k=1 greedy (acceptance is exact for
    greedy self-speculation), with a nonzero draft-acceptance rate.
(d) chunked prefill (cap_t smaller than the prompts) == whole-prompt
    prefill, on a sliding-window arch (gemma3 reduced).

Host-level (no jax):

(b) KV slot pool invariants under random admission/completion
    (hypothesis), plus scheduler packing laws (budgets, capacity, the
    per-request item-ordering constraint chunk pipelining relies on) and
    the speculative draft/verify rules.
"""

import os
import subprocess
import sys
import textwrap

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import (KVSlotPool, SchedulerConfig, Segment,
                         TickScheduler, propose_draft, verify_greedy)

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    jax.config.update("jax_default_matmul_precision", "highest")
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             one_shot_generate)

    def llama():
        return get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                               n_heads=4, head_dim=16,
                                               vocab=256)

    def gemma():
        # n_layers=5 puts one GLOBAL layer (idx 4) among the window-8
        # locals, so both mask paths run
        return get_arch("gemma3-1b").reduced(n_layers=5, d_model=64,
                                             n_heads=4, head_dim=16,
                                             vocab=256)

    def trace(n, seed=7, lo=3, hi=28, max_new=6, spread=0.4):
        rng = np.random.default_rng(seed)
        out = []
        for i in range(n):
            ln = int(rng.integers(lo, hi))
            out.append(Request(
                req_id=i, prompt=rng.integers(0, 256, ln).astype(np.int32),
                max_new_tokens=max_new, arrival=float(i) * spread))
        return out

    def run_engine(cfg, mesh, econf, reqs, params=None, cache=None,
                   seed=3):
        eng = ServeEngine(cfg, mesh, econf, params=params,
                          param_dtype=jnp.float32, cache=cache, seed=seed)
        res = eng.run(reqs)
        return eng, {r: res[r].output_ids for r in res}
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c",
                        _COMMON + textwrap.dedent(case)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# (a) engine == one-shot path at k=1; bucket set closed on a replay
# ---------------------------------------------------------------------------

def test_engine_matches_one_shot_and_bucket_closure():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        econf = EngineConfig(n_items=4, cap_t=16, n_slots=4, s_cap=48, k=1)
        reqs = trace(20, max_new=5)
        eng, got = run_engine(cfg, mesh, econf, reqs)
        assert len(got) == 20, got.keys()

        # one bucket total; replaying the identical trace compiles nothing
        assert eng.cache.stats.misses == 1, eng.cache.stats.as_dict()
        eng2, got2 = run_engine(cfg, mesh, econf, trace(20, max_new=5),
                                params=eng.params, cache=eng.cache)
        assert eng.cache.stats.misses == 1, eng.cache.stats.as_dict()
        assert got2 == got

        # the one-shot serve path (no continuous batching, no KV reuse)
        # produces identical ids for every request
        ref = one_shot_generate(cfg, mesh, eng.params,
                                [r.prompt for r in reqs], 5)
        for r in reqs:
            assert got[r.req_id] == ref[r.req_id], (
                r.req_id, len(r.prompt), got[r.req_id], ref[r.req_id])
        print("OK one-shot parity", sum(map(len, got.values())))
    """)


# ---------------------------------------------------------------------------
# (c) speculative k=2 == k=1 greedy (exact acceptance), drafts accepted
# ---------------------------------------------------------------------------

def test_speculative_k2_matches_k1():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        reqs = lambda: trace(8, seed=11, max_new=6)
        e1, g1 = run_engine(
            cfg, mesh, EngineConfig(n_items=4, cap_t=16, n_slots=4,
                                    s_cap=48, k=1), reqs())
        e2, g2 = run_engine(
            cfg, mesh, EngineConfig(n_items=4, cap_t=16, n_slots=4,
                                    s_cap=48, k=2), reqs(),
            params=e1.params)
        assert g2 == g1, (g1, g2)
        sp = e2.spec_stats
        assert sp.drafted > 0 and sp.decode_ticks > 0
        # zipf-ish tokens repeat, so the n-gram self-draft must land some
        assert sp.accepted > 0, sp.as_dict()
        assert e1.spec_stats.drafted == 0
        print("OK speculative", sp.as_dict())
    """)


# ---------------------------------------------------------------------------
# (d) chunked prefill == whole-prompt prefill (sliding-window arch)
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole_prompt():
    _run("""
        cfg = gemma()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        reqs = lambda: trace(6, seed=5, lo=10, hi=30, max_new=4)
        # cap_t=8 slices every prompt into multiple pipelined chunks;
        # cap_t=32 prefills each prompt whole
        e_chunk, g_chunk = run_engine(
            cfg, mesh, EngineConfig(n_items=6, cap_t=8, n_slots=4,
                                    s_cap=64, k=1), reqs())
        e_whole, g_whole = run_engine(
            cfg, mesh, EngineConfig(n_items=4, cap_t=32, n_slots=4,
                                    s_cap=64, k=1), reqs(),
            params=e_chunk.params)
        assert g_chunk == g_whole, (g_chunk, g_whole)
        ref = one_shot_generate(cfg, mesh, e_chunk.params,
                                [r.prompt for r in reqs()], 4)
        assert g_chunk == {i: ref[i] for i in range(len(ref))}
        print("OK chunked prefill", g_chunk[0])
    """)


# ---------------------------------------------------------------------------
# (b) KV slot pool invariants under random admission/completion
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.integers(1, 12),
       st.lists(st.tuples(st.booleans(), st.integers(0, 30)), max_size=80))
def test_slot_pool_invariants(n_slots, ops):
    pool = KVSlotPool(n_slots, s_cap=32)
    live = {}
    next_req = 0
    for is_alloc, pick in ops:
        if is_alloc:
            slot = pool.alloc(next_req)
            if slot is None:
                assert len(live) == n_slots   # only a full pool fails
            else:
                assert 0 <= slot < n_slots    # trash slot never handed out
                live[next_req] = slot
                next_req += 1
        elif live:
            rid = sorted(live)[pick % len(live)]
            assert pool.free(live.pop(rid)) == rid
        pool.check()
        assert pool.in_use == len(live)
        assert pool.in_use + pool.n_free == n_slots
    assert pool.stats.allocs == len(live) + pool.stats.frees
    assert pool.stats.peak_in_use <= n_slots


def test_slot_pool_errors_and_preemption():
    pool = KVSlotPool(2, s_cap=8)
    a = pool.alloc(10)
    b = pool.alloc(11)
    assert {a, b} == {0, 1}
    assert pool.alloc(12) is None
    assert pool.stats.alloc_failures == 1
    with pytest.raises(ValueError):
        pool.alloc(10)          # double admission
    assert pool.preempt(a) == 10
    assert pool.stats.preemptions == 1
    with pytest.raises(ValueError):
        pool.free(a)            # double free
    pool.check()


# ---------------------------------------------------------------------------
# scheduler packing laws
# ---------------------------------------------------------------------------

def _dec(rid, k=1, slot=0, base=10):
    return Segment(req_id=rid, kind="decode", tokens=tuple(range(k)),
                   slot=slot, base=base)


def _pre(rid, lens, slot=1):
    segs, off = [], 0
    for ln in lens:
        segs.append(Segment(req_id=rid, kind="prefill",
                            tokens=tuple(range(ln)), slot=slot, base=off))
        off += ln
    return segs


def test_scheduler_capacity_and_ordering():
    sched = TickScheduler(SchedulerConfig(n_items=3, cap_t=8, k=1))
    plan = sched.plan([_dec(0), _dec(1)], [_pre(2, [8, 8, 8, 8])])
    # never over cap_t per item
    for item in plan.items:
        assert sum(len(s.tokens) for s in item) <= 8
    # same-request segments in strictly increasing item indices (the
    # pipeline ordering that makes chunk j+1 see chunk j's cache writes)
    seen = {}
    for i, item in enumerate(plan.items):
        for s in item:
            assert seen.get(s.req_id, -1) < i
            seen[s.req_id] = i
    # chunk 4 of request 2 cannot fit this step and is deferred, never
    # reordered or truncated
    placed_pre = [s for it in plan.items for s in it if s.req_id == 2]
    assert [s.base for s in placed_pre] == sorted(s.base for s in placed_pre)
    assert plan.deferred_prefill == 1
    assert plan.decode_tokens == 2


def test_scheduler_budgets_and_serial_mode():
    # decode budget caps streams per step (round-robin defers the rest)
    sched = TickScheduler(SchedulerConfig(n_items=2, cap_t=4, k=2,
                                          decode_token_budget=4))
    plan = sched.plan([_dec(i, k=2, slot=i) for i in range(4)], [])
    assert plan.decode_tokens == 4 and plan.deferred_decode == 2
    # round-robin start rotates so deferred streams go first next step
    plan2 = sched.plan([_dec(i, k=2, slot=i) for i in range(4)], [])
    first_ids = {s.req_id for it in plan.items for s in it}
    second_ids = {s.req_id for it in plan2.items for s in it}
    assert first_ids != second_ids
    # serial (stop-the-world) mode: no decode while prefill is pending
    sched = TickScheduler(SchedulerConfig(n_items=2, cap_t=8, k=1,
                                          prefill_mode="serial"))
    plan = sched.plan([_dec(0)], [_pre(1, [8])])
    kinds = {s.kind for it in plan.items for s in it}
    assert kinds == {"prefill"} and plan.deferred_decode == 1
    # ...and decodes run once nothing is prefilling
    plan = sched.plan([_dec(0)], [])
    assert {s.kind for it in plan.items for s in it} == {"decode"}


# ---------------------------------------------------------------------------
# speculative draft/verify rules (host-side)
# ---------------------------------------------------------------------------

def test_verify_greedy_rules():
    # k=1: emit exactly the model's one id
    assert verify_greedy([5], [9]) == [9]
    # full acceptance: drafts equal the model's ids shifted by one
    assert verify_greedy([5, 9, 4], [9, 4, 7]) == [9, 4, 7]
    # first disagreement stops acceptance; its correction is emitted
    assert verify_greedy([5, 9, 4], [9, 8, 7]) == [9, 8]
    assert verify_greedy([5, 1, 4], [9, 8, 7]) == [9]
    with pytest.raises(ValueError):
        verify_greedy([5, 9], [1])


def test_propose_draft_ngram_lookup():
    # the continuation of the last occurrence of the suffix is proposed
    hist = [1, 2, 3, 7, 8, 1, 2, 3]
    assert propose_draft(hist, 2, ngram=3) == [7, 8]
    # no match: repeat the last token
    assert propose_draft([4, 5, 6], 3, ngram=3) == [6, 6, 6]
    assert propose_draft([], 2) == [0, 0]
    assert propose_draft(hist, 0) == []
    # deterministic and bounded
    assert len(propose_draft(hist, 5)) == 5


# ---------------------------------------------------------------------------
# preemption: starvation evicts a decode stream; outputs NEVER change
# ---------------------------------------------------------------------------

def test_preemption_preserves_outputs():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        reqs = lambda: trace(5, seed=9, lo=4, hi=16, max_new=6, spread=0.0)
        # 2 slots for 5 simultaneous requests + aggressive preemption:
        # queue-head starvation must evict decode streams...
        tight = EngineConfig(n_items=4, cap_t=16, n_slots=2, s_cap=48,
                             k=1, preempt_waiting_steps=2)
        e_t, g_t = run_engine(cfg, mesh, tight, reqs())
        assert e_t.pool.stats.preemptions > 0, e_t.pool.stats.as_dict()
        assert any(r.preempted for r in e_t.results.values())
        # ...and greedy determinism means the emitted ids are identical to
        # an uncontended run (only latency moves)
        roomy = EngineConfig(n_items=4, cap_t=16, n_slots=5, s_cap=48, k=1)
        e_r, g_r = run_engine(cfg, mesh, roomy, reqs(), params=e_t.params)
        assert e_r.pool.stats.preemptions == 0
        assert g_t == g_r, (g_t, g_r)
        print("OK preemption", e_t.pool.stats.as_dict())
    """)


def test_run_records_rejections_instead_of_aborting():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        econf = EngineConfig(n_items=4, cap_t=16, n_slots=4, s_cap=32, k=1)
        eng = ServeEngine(cfg, mesh, econf, param_dtype=jnp.float32, seed=3)
        reqs = trace(3, seed=2, lo=4, hi=10, max_new=4)
        # prompt + max_new exceeds s_cap: rejected, not fatal, and the
        # rest of the trace still completes
        reqs.append(Request(req_id=99,
                            prompt=np.zeros(40, np.int32),
                            max_new_tokens=4, arrival=0.0))
        res = eng.run(reqs)
        assert sorted(res) == [0, 1, 2]
        assert list(eng.rejected) == [99], eng.rejected
        assert "never silently truncated" in eng.rejected[99]
        assert eng.stats()["rejected"] == 1
        print("OK rejection", eng.rejected[99][:40])
    """)
