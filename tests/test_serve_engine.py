"""Serving-engine guarantees.

Device-level (subprocess, 4 fake CPU devices, 2x2 mesh — see
test_executor_core.py for the pattern):

(a) continuous-batching engine greedy ids == the one-shot serve path
    (whole-prompt prefill + teacher-forced recompute, no KV reuse) at
    k=1, over a staggered multi-request trace — and the engine's
    compile-cache bucket set is CLOSED at exactly two buckets (step +
    COW copy): a second identical trace pass compiles nothing.
(c) speculative k=2 output ids == k=1 greedy (acceptance is exact for
    greedy self-speculation), with a nonzero draft-acceptance rate.
(d) chunked prefill (cap_t smaller than the prompts) == whole-prompt
    prefill, on a sliding-window arch (gemma3 reduced).
(e) prefix cache: a shared-system-prompt trace produces identical
    output ids with the cache on and off, while the cached run feeds
    exactly ``prefix_hit_rows`` fewer prompt tokens (>= 40% here).
(f) preemption under page pressure never changes output ids; tpot is
    reported as None (and excluded from stats) for single-token
    requests instead of a fake 0.

Host-level (no jax):

(b) paged-KV-pool invariants under random admission/append/free/preempt
    (hypothesis; ``PagedKVPool.check`` asserts the free/referenced
    partition, refcount == table membership and trash-page containment
    after every op), prefix-cache publish/match/adopt/COW semantics,
    scheduler packing laws (budgets, capacity, per-request item
    ordering, per-CHUNK deferral counting) and the round-robin
    starvation regression.
"""

import os
import subprocess
import sys
import textwrap
from collections import Counter

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import (PagedKVPool, SchedulerConfig, Segment,
                         TickScheduler, propose_draft, verify_greedy)

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    jax.config.update("jax_default_matmul_precision", "highest")
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             one_shot_generate)

    def llama():
        return get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                               n_heads=4, head_dim=16,
                                               vocab=256)

    def gemma():
        # n_layers=5 puts one GLOBAL layer (idx 4) among the window-8
        # locals, so both mask paths run
        return get_arch("gemma3-1b").reduced(n_layers=5, d_model=64,
                                             n_heads=4, head_dim=16,
                                             vocab=256)

    def trace(n, seed=7, lo=3, hi=28, max_new=6, spread=0.4):
        rng = np.random.default_rng(seed)
        out = []
        for i in range(n):
            ln = int(rng.integers(lo, hi))
            out.append(Request(
                req_id=i, prompt=rng.integers(0, 256, ln).astype(np.int32),
                max_new_tokens=max_new, arrival=float(i) * spread))
        return out

    def run_engine(cfg, mesh, econf, reqs, params=None, cache=None,
                   seed=3):
        eng = ServeEngine(cfg, mesh, econf, params=params,
                          param_dtype=jnp.float32, cache=cache, seed=seed)
        res = eng.run(reqs)
        return eng, {r: res[r].output_ids for r in res}
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c",
                        _COMMON + textwrap.dedent(case)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# (a) engine == one-shot path at k=1; bucket set closed on a replay
# ---------------------------------------------------------------------------

def test_engine_matches_one_shot_and_bucket_closure():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        econf = EngineConfig(n_items=4, cap_t=16, n_pages=24, page_sz=8,
                             pages_per_seq=6, k=1)
        reqs = trace(20, max_new=5)
        eng, got = run_engine(cfg, mesh, econf, reqs)
        assert len(got) == 20, got.keys()

        # exactly two buckets (engine step + COW copy, the copy program
        # built eagerly); replaying the identical trace compiles nothing
        assert eng.cache.stats.misses == 2, eng.cache.stats.as_dict()
        eng2, got2 = run_engine(cfg, mesh, econf, trace(20, max_new=5),
                                params=eng.params, cache=eng.cache)
        assert eng.cache.stats.misses == 2, eng.cache.stats.as_dict()
        assert got2 == got

        # the one-shot serve path (no continuous batching, no KV reuse)
        # produces identical ids for every request
        ref = one_shot_generate(cfg, mesh, eng.params,
                                [r.prompt for r in reqs], 5)
        for r in reqs:
            assert got[r.req_id] == ref[r.req_id], (
                r.req_id, len(r.prompt), got[r.req_id], ref[r.req_id])
        print("OK one-shot parity", sum(map(len, got.values())))
    """)


# ---------------------------------------------------------------------------
# (c) speculative k=2 == k=1 greedy (exact acceptance), drafts accepted
# ---------------------------------------------------------------------------

def test_speculative_k2_matches_k1():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        reqs = lambda: trace(8, seed=11, max_new=6)
        e1, g1 = run_engine(
            cfg, mesh, EngineConfig(n_items=4, cap_t=16, n_pages=24,
                                    page_sz=8, pages_per_seq=6, k=1), reqs())
        e2, g2 = run_engine(
            cfg, mesh, EngineConfig(n_items=4, cap_t=16, n_pages=24,
                                    page_sz=8, pages_per_seq=6, k=2), reqs(),
            params=e1.params)
        assert g2 == g1, (g1, g2)
        sp = e2.spec_stats
        assert sp.drafted > 0 and sp.decode_ticks > 0
        # zipf-ish tokens repeat, so the n-gram self-draft must land some
        assert sp.accepted > 0, sp.as_dict()
        assert e1.spec_stats.drafted == 0
        print("OK speculative", sp.as_dict())
    """)


# ---------------------------------------------------------------------------
# (d) chunked prefill == whole-prompt prefill (sliding-window arch)
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole_prompt():
    _run("""
        cfg = gemma()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        reqs = lambda: trace(6, seed=5, lo=10, hi=30, max_new=4)
        # cap_t=8 slices every prompt into multiple pipelined chunks;
        # cap_t=32 prefills each prompt whole
        e_chunk, g_chunk = run_engine(
            cfg, mesh, EngineConfig(n_items=6, cap_t=8, n_pages=32,
                                    page_sz=8, pages_per_seq=8, k=1), reqs())
        e_whole, g_whole = run_engine(
            cfg, mesh, EngineConfig(n_items=4, cap_t=32, n_pages=32,
                                    page_sz=8, pages_per_seq=8, k=1), reqs(),
            params=e_chunk.params)
        assert g_chunk == g_whole, (g_chunk, g_whole)
        ref = one_shot_generate(cfg, mesh, e_chunk.params,
                                [r.prompt for r in reqs()], 4)
        assert g_chunk == {i: ref[i] for i in range(len(ref))}
        print("OK chunked prefill", g_chunk[0])
    """)


# ---------------------------------------------------------------------------
# (e) prefix cache: bitwise-equal outputs, exact prefill-token accounting
# ---------------------------------------------------------------------------

def test_prefix_cache_parity_and_savings():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        # shared 16-token system prompt (= 2 full pages) + unique tails,
        # staggered so request 0's pages are published before the rest admit
        rng = np.random.default_rng(17)
        sysp = rng.integers(0, 256, 16).astype(np.int32)
        reqs = []
        for i in range(8):
            tail = rng.integers(0, 256,
                                int(rng.integers(5, 11))).astype(np.int32)
            reqs.append(Request(req_id=i,
                                prompt=np.concatenate([sysp, tail]),
                                max_new_tokens=4, arrival=float(i) * 2.0))
        geom = dict(n_items=4, cap_t=16, n_pages=24, page_sz=8,
                    pages_per_seq=5, k=1)
        e_on, g_on = run_engine(cfg, mesh, EngineConfig(**geom), list(reqs))
        e_off, g_off = run_engine(
            cfg, mesh, EngineConfig(prefix_cache=False, **geom),
            list(reqs), params=e_on.params)
        # sharing may never change what comes out
        assert g_on == g_off, (g_on, g_off)
        hits = e_on.pool.stats.prefix_hit_rows
        assert hits > 0, e_on.pool.stats.as_dict()
        assert e_off.pool.stats.prefix_hit_rows == 0
        fed_on = e_on.stats()["prefill_tokens_fed"]
        fed_off = e_off.stats()["prefill_tokens_fed"]
        # every adopted row is a prompt token NOT fed — exact accounting
        assert fed_on + hits == fed_off, (fed_on, hits, fed_off)
        assert (fed_off - fed_on) / fed_off >= 0.40, (fed_on, fed_off)
        print("OK prefix cache", fed_on, "of", fed_off, "fed")
    """)


# ---------------------------------------------------------------------------
# (b) paged pool invariants under random admission/append/free/preempt
# ---------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(st.integers(2, 12), st.integers(1, 6), st.booleans(),
       st.lists(st.tuples(st.integers(0, 3), st.integers(0, 1000)),
                max_size=80))
def test_paged_pool_invariants(n_pages, page_sz, cache, ops):
    pool = PagedKVPool(n_pages, page_sz, prefix_cache=cache)
    live = {}                    # rid -> pages appended (table length)
    next_rid = 0
    for action, pick in ops:
        if action == 0:
            pool.alloc_table(next_rid)
            live[next_rid] = 0
            next_rid += 1
        elif action == 1 and live:
            rid = sorted(live)[pick % len(live)]
            page = pool.append_page(rid)
            if page is None:
                assert pool.n_free == 0      # only an exhausted pool fails
            else:
                assert 0 <= page < n_pages   # trash page never handed out
                live[rid] += 1
        elif action == 2 and live:
            rid = sorted(live)[pick % len(live)]
            # publish the full pages, then finish: freed pages stay cached
            toks = [(rid * 131 + j) % 7
                    for j in range(live.pop(rid) * page_sz)]
            pool.publish_ready(rid, toks, len(toks))
            pool.free_table(rid)
        elif action == 3 and live:
            rid = sorted(live)[pick % len(live)]
            pool.preempt(rid)
            del live[rid]
        pool.check()
        assert pool.n_seqs == len(live)
        assert pool.in_use + pool.n_free == n_pages
        assert pool.table_of(12345) is None
    assert pool.stats.peak_in_use <= n_pages


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=24),
       st.lists(st.integers(0, 3), min_size=1, max_size=24),
       st.integers(1, 4))
def test_prefix_match_never_exceeds_true_common_prefix(a, b, ps):
    pool = PagedKVPool(16, ps)
    pool.alloc_table(1)
    for _ in range(-(-len(a) // ps)):
        pool.append_page(1)
    pool.publish_ready(1, a, len(a))
    pool.free_table(1)
    pages, rows = pool.match_prefix(b, len(b))
    common = 0
    while common < min(len(a), len(b)) and a[common] == b[common]:
        common += 1
    # matched rows are a TRUE shared prefix (hash + token comparison),
    # never an overclaim — this is what makes adoption bitwise-safe
    assert rows <= common, (a, b, rows, common)
    assert len(pages) <= -(-rows // ps) + (1 if rows == 0 else 0)
    pool.check()


def test_prefix_cache_publish_match_adopt_roundtrip():
    pool = PagedKVPool(8, 4)
    pool.alloc_table(1)
    for _ in range(3):
        pool.append_page(1)
    toks = list(range(10))
    pool.publish_ready(1, toks, committed=10)    # 2 full pages published
    assert pool.stats.published == 2
    t1 = list(pool.table_of(1))
    pool.free_table(1)
    pool.check()
    # same prompt: the SAME page ids come back (the device rows are
    # reused verbatim, the definition of a bitwise prefix hit)
    pages, rows = pool.match_prefix(toks, max_rows=9)
    assert rows == 8 and pages == t1[:2]
    pool.alloc_table(2)
    pool.adopt_prefix(2, pages, rows)
    assert pool.refcount(t1[0]) == 1
    assert pool.stats.prefix_hit_rows == 8
    pool.check()
    # a prompt diverging INSIDE page 2 partially matches it (shared rows
    # only up to the divergence point)
    div = toks[:6] + [99, 98]
    p2, r2 = pool.match_prefix(div, max_rows=8)
    assert r2 == 6 and p2 == t1[:2]


def test_cow_never_mutates_shared_page():
    pool = PagedKVPool(6, 4)
    pool.alloc_table(1)
    pool.append_page(1)
    pool.append_page(1)
    toks = list(range(8))
    pool.publish_ready(1, toks, 8)
    pool.alloc_table(2)
    pages, rows = pool.match_prefix(toks[:6] + [50, 51], 7)
    assert rows == 6 and len(pages) == 2         # full page + partial tail
    pool.adopt_prefix(2, pages, rows)
    shared = pages[1]
    assert pool.refcount(shared) == 2
    status, pair = pool.ensure_writable(2, 1)
    assert status == "cow" and pair[0] == shared
    # the shared page is untouched: still in table 1, still published
    assert pool.table_of(1)[1] == shared
    assert pool.is_published(shared)
    assert pool.refcount(shared) == 1
    assert pool.table_of(2)[1] == pair[1] != shared
    assert pool.stats.cow_copies == 1
    pool.check()


def test_ensure_writable_unpublishes_sole_owner_in_place():
    pool = PagedKVPool(4, 4)
    pool.alloc_table(1)
    pool.append_page(1)
    pool.publish_ready(1, list(range(4)), 4)
    p = pool.table_of(1)[0]
    assert pool.is_published(p)
    status, pair = pool.ensure_writable(1, 0)
    assert status == "ok" and pair is None       # in place, hash dropped
    assert not pool.is_published(p)
    assert pool.stats.cow_copies == 0
    pool.check()


def test_cached_free_pages_are_evicted_lru():
    pool = PagedKVPool(2, 2)
    pool.alloc_table(1)
    pool.append_page(1)
    pool.append_page(1)
    pool.publish_ready(1, [1, 2, 3, 4], 4)
    pool.free_table(1)
    assert pool.n_free == 2                      # free-but-cached
    # a fresh allocation reuses the LRU cached page and drops its hash
    pool.alloc_table(2)
    p = pool.append_page(2)
    assert not pool.is_published(p)
    assert pool.stats.cache_evictions == 1
    # the evicted page headed the chain, so the whole prefix stops matching
    pages, rows = pool.match_prefix([1, 2, 3, 4], 4)
    assert rows == 0 and pages == []
    pool.check()


def test_paged_pool_errors_and_exhaustion():
    pool = PagedKVPool(2, 4)
    pool.alloc_table(1)
    with pytest.raises(ValueError):
        pool.alloc_table(1)                      # double admission
    assert pool.append_page(1) is not None
    assert pool.append_page(1) is not None
    assert pool.append_page(1) is None           # exhausted, not fatal
    assert pool.stats.alloc_failures == 1
    with pytest.raises(ValueError):
        pool.free_table(2)                       # unknown request
    assert len(pool.preempt(1)) == 2
    assert pool.stats.preemptions == 1
    assert pool.n_free == 2 and pool.in_use == 0
    pool.check()


# ---------------------------------------------------------------------------
# scheduler packing laws
# ---------------------------------------------------------------------------

def _dec(rid, k=1, base=10):
    return Segment(req_id=rid, kind="decode", tokens=tuple(range(k)),
                   base=base)


def _pre(rid, lens):
    segs, off = [], 0
    for ln in lens:
        segs.append(Segment(req_id=rid, kind="prefill",
                            tokens=tuple(range(ln)), base=off))
        off += ln
    return segs


def test_scheduler_capacity_and_ordering():
    sched = TickScheduler(SchedulerConfig(n_items=3, cap_t=8, k=1))
    plan = sched.plan([_dec(0), _dec(1)], [_pre(2, [8, 8, 8, 8])])
    # never over cap_t per item
    for item in plan.items:
        assert sum(len(s.tokens) for s in item) <= 8
    # same-request segments in strictly increasing item indices (the
    # pipeline ordering that makes chunk j+1 see chunk j's cache writes)
    seen = {}
    for i, item in enumerate(plan.items):
        for s in item:
            assert seen.get(s.req_id, -1) < i
            seen[s.req_id] = i
    # chunks 3 and 4 of request 2 cannot fit this step: BOTH are counted
    # deferred (the field is a chunk count — counting one per request
    # undercounted deferral on skewed traces), never reordered/truncated
    placed_pre = [s for it in plan.items for s in it if s.req_id == 2]
    assert len(placed_pre) == 2
    assert [s.base for s in placed_pre] == sorted(s.base for s in placed_pre)
    assert plan.deferred_prefill == 2
    assert plan.decode_tokens == 2


def test_scheduler_budgets_and_serial_mode():
    # decode budget caps streams per step (round-robin defers the rest)
    sched = TickScheduler(SchedulerConfig(n_items=2, cap_t=4, k=2,
                                          decode_token_budget=4))
    plan = sched.plan([_dec(i, k=2) for i in range(4)], [])
    assert plan.decode_tokens == 4 and plan.deferred_decode == 2
    # round-robin start rotates so deferred streams go first next step
    plan2 = sched.plan([_dec(i, k=2) for i in range(4)], [])
    first_ids = {s.req_id for it in plan.items for s in it}
    second_ids = {s.req_id for it in plan2.items for s in it}
    assert first_ids != second_ids
    # serial (stop-the-world) mode: no decode while prefill is pending
    sched = TickScheduler(SchedulerConfig(n_items=2, cap_t=8, k=1,
                                          prefill_mode="serial"))
    plan = sched.plan([_dec(0)], [_pre(1, [8])])
    kinds = {s.kind for it in plan.items for s in it}
    assert kinds == {"prefill"} and plan.deferred_decode == 1
    # ...and decodes run once nothing is prefilling
    plan = sched.plan([_dec(0)], [])
    assert {s.kind for it in plan.items for s in it} == {"decode"}


def test_scheduler_round_robin_starvation_regression():
    # the rotation is keyed on stable req_id order, not an index into the
    # CURRENT candidate list — with a fixed population every stream must
    # be served the same number of times over a full cycle
    sched = TickScheduler(SchedulerConfig(n_items=2, cap_t=4, k=2,
                                          decode_token_budget=4))
    ids = [3, 7, 11, 20]
    served = Counter()
    for _ in range(8):                       # 2 cycles of 4 streams
        plan = sched.plan([_dec(i, k=2) for i in ids], [])
        for it in plan.items:
            for s in it:
                served[s.req_id] += 1
    assert served == {i: 4 for i in ids}, served
    # population churn: a stream completing mid-rotation must not leave
    # any survivor persistently ordered last (the old index-mod-len bug)
    sched = TickScheduler(SchedulerConfig(n_items=2, cap_t=4, k=2,
                                          decode_token_budget=4))
    pop = [0, 1, 2, 3]
    served = Counter()
    for step in range(9):
        plan = sched.plan([_dec(i, k=2) for i in pop], [])
        for it in plan.items:
            for s in it:
                served[s.req_id] += 1
        if step == 1:
            pop.remove(1)
    survivors = [served[i] for i in pop]
    assert min(survivors) > 0
    assert max(survivors) - min(survivors) <= 1, (served, pop)


# ---------------------------------------------------------------------------
# speculative draft/verify rules (host-side)
# ---------------------------------------------------------------------------

def test_verify_greedy_rules():
    # k=1: emit exactly the model's one id
    assert verify_greedy([5], [9]) == [9]
    # full acceptance: drafts equal the model's ids shifted by one
    assert verify_greedy([5, 9, 4], [9, 4, 7]) == [9, 4, 7]
    # first disagreement stops acceptance; its correction is emitted
    assert verify_greedy([5, 9, 4], [9, 8, 7]) == [9, 8]
    assert verify_greedy([5, 1, 4], [9, 8, 7]) == [9]
    with pytest.raises(ValueError):
        verify_greedy([5, 9], [1])


def test_propose_draft_ngram_lookup():
    # the continuation of the last occurrence of the suffix is proposed
    hist = [1, 2, 3, 7, 8, 1, 2, 3]
    assert propose_draft(hist, 2, ngram=3) == [7, 8]
    # no match: repeat the last token
    assert propose_draft([4, 5, 6], 3, ngram=3) == [6, 6, 6]
    assert propose_draft([], 2) == [0, 0]
    assert propose_draft(hist, 0) == []
    # deterministic and bounded
    assert len(propose_draft(hist, 5)) == 5


# ---------------------------------------------------------------------------
# (f) preemption under page pressure; outputs NEVER change
# ---------------------------------------------------------------------------

def test_preemption_preserves_outputs():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        reqs = lambda: trace(5, seed=9, lo=12, hi=21, max_new=8, spread=1.0)
        # 6 pages for 5 staggered requests wanting up to 4 each: arrivals
        # 2+ hit an occupied pool (pages are charged on write, so the
        # admission gate only bites once earlier streams hold real pages)
        # and queue-head starvation must evict decode streams...
        tight = EngineConfig(n_items=4, cap_t=24, n_pages=6, page_sz=8,
                             pages_per_seq=4, k=1, preempt_waiting_steps=2)
        e_t, g_t = run_engine(cfg, mesh, tight, reqs())
        assert e_t.pool.stats.preemptions > 0, e_t.pool.stats.as_dict()
        assert any(r.preempted for r in e_t.results.values())
        # ...and greedy determinism means the emitted ids are identical to
        # an uncontended run (only latency moves)
        roomy = EngineConfig(n_items=4, cap_t=24, n_pages=20, page_sz=8,
                             pages_per_seq=4, k=1)
        e_r, g_r = run_engine(cfg, mesh, roomy, reqs(), params=e_t.params)
        assert e_r.pool.stats.preemptions == 0
        assert g_t == g_r, (g_t, g_r)
        print("OK preemption", e_t.pool.stats.as_dict())
    """)


def test_rejections_and_tpot_reporting():
    _run("""
        cfg = llama()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        econf = EngineConfig(n_items=4, cap_t=16, n_pages=16, page_sz=8,
                             pages_per_seq=4, k=1)
        eng = ServeEngine(cfg, mesh, econf, param_dtype=jnp.float32, seed=3)
        reqs = trace(3, seed=2, lo=4, hi=10, max_new=4)
        # two single-token requests: tpot must come back None, not 0.0
        reqs += [Request(req_id=10 + i,
                         prompt=(np.arange(5 + i) % 256).astype(np.int32),
                         max_new_tokens=1, arrival=0.0) for i in range(2)]
        # prompt + max_new exceeds pages_per_seq * page_sz: rejected, not
        # fatal, and the rest of the trace still completes
        reqs.append(Request(req_id=99,
                            prompt=np.zeros(40, np.int32),
                            max_new_tokens=4, arrival=0.0))
        res = eng.run(reqs)
        assert sorted(res) == [0, 1, 2, 10, 11]
        assert list(eng.rejected) == [99], eng.rejected
        assert "never silently truncated" in eng.rejected[99]
        st = eng.stats()
        assert st["rejected"] == 1
        # single-token requests report tpot_s=None and are EXCLUDED from
        # the percentiles (reporting 0.0 biased them optimistic)
        ones = [r for r in res.values() if len(r.output_ids) == 1]
        multi = [r for r in res.values() if len(r.output_ids) > 1]
        assert len(ones) == 2 and len(multi) == 3
        assert all(r.tpot_s is None for r in ones)
        assert all(r.tpot_s is not None and r.tpot_s >= 0 for r in multi)
        assert st["tpot_measured"] == len(multi)
        print("OK rejection+tpot", eng.rejected[99][:40])
    """)
