"""ReplanController: drift detection -> calibrated re-solve -> hysteresis-
gated hot-swap, plus persistence and the observe-mode non-intrusiveness
contract. Pure planner level — no XLA."""

import numpy as np
import pytest

from repro.core import CostModel, PlannerConfig, plan_batch
from repro.core.planner import estimate_plan_time
from repro.telemetry import ReplanConfig, ReplanController
from repro.telemetry.calibrate import plan_components

D_S = 4


def _lengths(seed, batch=8, lo=256, hi=32768, mu=8.0):
    rng = np.random.default_rng(seed)
    return [int(x) for x in np.clip(rng.lognormal(mu, 1, size=batch), lo, hi)]


def _solve(cm, lengths):
    return plan_batch(cm, lengths, PlannerConfig())


def _bucket(plan):
    return str(plan.bucket_key(D_S))


def _held(cm, lengths, inc):
    key = inc.bucket_key(D_S)
    return plan_batch(cm, lengths,
                      PlannerConfig(token_capacity=key.cap,
                                    sp_policy=key.sp_policy,
                                    sp_degree=key.d_s_eff))


def _controller(cm, mode="auto", **kw):
    defaults = dict(mode=mode, min_samples=3, cooldown_steps=2,
                    background=False)
    defaults.update(kw.pop("cfg", {}))
    return ReplanController(cm, ReplanConfig(**defaults), _solve, _bucket,
                            resolve_incumbent=_held, **kw)


def _drive(controller, cm_truth_fn, steps, comm_fn=None, noise=0.01, seed=0):
    """Feed `steps` synthetic steps; measured = truth-model makespan."""
    rng = np.random.default_rng(seed)
    decisions = []
    for step in range(steps):
        truth = cm_truth_fn(step)
        lengths = _lengths(100 + step % 3)
        plan = _solve(controller.cost_model(), lengths)
        wall = estimate_plan_time(truth, plan)
        wall *= 1 + noise * rng.standard_normal()
        slow = truth.stage_slowdowns or [1.0] * truth.cluster.d_p
        probes = [wall / len(slow) * s for s in slow]
        comm_s = comm_fn(truth, plan) if comm_fn else None
        controller.observe_step(step, plan, wall, lengths,
                                per_stage_s=probes, comm_s=comm_s)
        dec = controller.poll()
        if dec is not None:
            decisions.append(dec)
    return decisions


def test_swap_on_straggler_and_bandwidth_drift(cost_model):
    """A mid-run bandwidth collapse + straggler must trigger a drift
    re-plan whose adopted plan moves to a cheaper bucket (the predicted
    win clears hysteresis) and is precompiled before adoption."""
    from dataclasses import replace
    drift_at = 6

    def truth(step):
        if step < drift_at:
            return cost_model
        co = replace(cost_model.coeffs,
                     ag_bw=cost_model.coeffs.ag_bw / 16,
                     a2a_bw=cost_model.coeffs.a2a_bw / 16)
        slow = [1.8 if p == 2 else 1.0
                for p in range(cost_model.cluster.d_p)]
        return CostModel(cost_model.model, cost_model.cluster, co,
                         stage_slowdowns=slow, ce_mode=cost_model.ce_mode)

    def comm_probe(tr, plan):
        # collective seconds on the critical path — what a profiler hook
        # reports: the makespan minus the same makespan over an infinitely
        # fast fabric. Same units as the measured wall, unlike the raw
        # component work.
        co = replace(tr.coeffs, ag_bw=tr.coeffs.ag_bw * 1e9,
                     a2a_bw=tr.coeffs.a2a_bw * 1e9)
        nocomm = CostModel(tr.model, tr.cluster, co,
                           stage_slowdowns=tr.stage_slowdowns,
                           ce_mode=tr.ce_mode)
        return max(0.0, estimate_plan_time(tr, plan)
                   - estimate_plan_time(nocomm, plan))

    precompiled = []
    c = _controller(cost_model, precompile=precompiled.append)
    decisions = _drive(c, truth, 18, comm_fn=comm_probe)
    swaps = [d for d in decisions if d.is_swap]
    assert c.counters["swaps"] >= 1, c.snapshot()
    d = swaps[0]
    assert d.step >= drift_at
    assert d.new_bucket != d.old_bucket
    assert d.win > c.cfg.min_win
    assert d.precompiled and precompiled, "swap must precompile pre-adoption"
    # the calibration driving it caught the collapse: comm re-priced far
    # above the compute terms (absolute scale is the unit conversion, so
    # only the RATIO is meaningful)
    assert c.active is not None
    assert c.active.comm_scales, "comm probe must pin a per-policy scale"
    compute = max(c.active.scales["lin"], c.active.scales["quad"])
    assert max(c.active.comm_scales.values()) > 4 * compute


def test_hysteresis_no_flap_on_noise(cost_model):
    """Pure measurement noise (a few %) on a stationary mix must never
    swap buckets: forced re-plans land within min_win and are rejected,
    and the adopted reference never moves. (A cycling mix is a different
    scenario — the drift test covers it — because a candidate solved for
    one mix can legitimately beat the incumbent's bucket on that batch.)"""
    c = _controller(cost_model, cfg={"min_win": 0.05})
    for step in range(12):
        lengths = _lengths(100)
        plan = _solve(c.cost_model(), lengths)
        wall = estimate_plan_time(cost_model, plan)
        wall *= 1 + 0.03 * np.random.default_rng(step).standard_normal()
        if step in (6, 9):
            c.force_replan("test-noise")
        c.observe_step(step, plan, wall, lengths)
        c.poll()
    assert c.counters["swaps"] == 0
    assert c.counters["forced"] == 2
    # forced jobs ran and resolved benignly — recalibrate (same bucket)
    # or hysteresis (sub-threshold win); either way nothing flapped
    assert (c.counters["recalibrations"]
            + c.counters["hysteresis_rejects"]) >= 1


def test_lint_rejects_hazardous_candidate(cost_model):
    """A candidate failing the plan lint must be rejected pre-swap, even
    with a large predicted win, and must not adopt its calibration."""
    c = _controller(
        cost_model,
        lint=lambda plan: ["E_TEST: synthetic hazard"],
        # huge measured inflation => candidate would win big
    )
    for step in range(8):
        lengths = _lengths(100 + step % 3)
        plan = _solve(c.cost_model(), lengths)
        wall = estimate_plan_time(cost_model, plan) * 3.0
        comm = plan_components(cost_model, plan)["comm"] * 40
        c.observe_step(step, plan, wall, lengths, comm_s=comm)
        c.poll()
    assert c.counters["swaps"] == 0
    if c.counters["lint_rejects"]:
        # a rejected candidate's calibration must not have been adopted
        # via the swap path (bootstrap/recalibrate adoptions are fine)
        assert all(s >= 0 for s in [c.version])
    assert c.counters["lint_rejects"] + c.counters["hysteresis_rejects"] >= 1


def test_observe_mode_never_touches_plans(cost_model):
    """observe: full machinery (fits, counters) but cost_model() stays the
    base model — plans and numerics are untouched."""
    c = _controller(cost_model, mode="observe")
    for step in range(8):
        lengths = _lengths(100 + step % 3)
        plan = _solve(c.cost_model(), lengths)
        wall = estimate_plan_time(cost_model, plan) * 2.0   # gross drift
        c.observe_step(step, plan, wall, lengths)
        c.poll()
    assert c.counters["fits"] >= 1
    assert c.active is not None, "observe still fits calibrations"
    assert c.cost_model() is cost_model, "observe must return the base model"
    assert c.counters["swaps"] == 0  # auto-only counter


def test_calibration_persistence_round_trip(cost_model, tmp_path):
    """An adopted calibration persists keyed by mesh fingerprint; a new
    controller on the same mesh warm-starts it."""
    c = _controller(cost_model, telemetry_dir=str(tmp_path),
                    fingerprint="4x4:tiny")
    for step in range(6):
        lengths = _lengths(100 + step % 3)
        plan = _solve(c.cost_model(), lengths)
        wall = estimate_plan_time(cost_model, plan) * 1.7
        c.observe_step(step, plan, wall, lengths)
        c.poll()
    assert c.active is not None
    assert (tmp_path / "calibration.json").exists()

    c2 = _controller(cost_model, telemetry_dir=str(tmp_path),
                     fingerprint="4x4:tiny")
    assert c2.active is not None
    assert c2.version == c.version
    assert c2.active.scales == pytest.approx(c.active.scales)


def test_foreign_fingerprint_forces_elastic_resolve(cost_model, tmp_path):
    """Calibrations exist but none for THIS mesh (elastic shrink/grow):
    the controller forces an immediate re-solve instead of replaying the
    bootstrap plan."""
    c = _controller(cost_model, telemetry_dir=str(tmp_path),
                    fingerprint="4x4:tiny")
    for step in range(6):
        lengths = _lengths(100 + step % 3)
        plan = _solve(c.cost_model(), lengths)
        c.observe_step(step, plan,
                       estimate_plan_time(cost_model, plan) * 1.7, lengths)
        c.poll()
    assert c.active is not None

    c2 = _controller(cost_model, telemetry_dir=str(tmp_path),
                     fingerprint="2x4:tiny")   # different mesh
    assert c2.active is None
    assert c2._force == "elastic"
    # the very next observed step launches the forced job
    lengths = _lengths(100)
    plan = _solve(c2.cost_model(), lengths)
    reason = c2.observe_step(0, plan,
                             estimate_plan_time(cost_model, plan), lengths)
    assert reason == "elastic"
    assert c2.counters["forced"] == 1


def test_warm_bucket_swap_is_compile_free(cost_model):
    """Swapping back to a previously-seen bucket must be a cache hit: the
    precompile closure runs against a warm CompileCache entry."""
    from repro.runtime.compile_cache import CompileCache
    cache = CompileCache(name="test-replan")
    built = []

    def precompile(plan):
        cache.get(_bucket(plan), lambda: built.append(_bucket(plan)))

    c = _controller(cost_model, precompile=precompile)
    seen = set()
    for step in range(6):
        lengths = _lengths(100 + step % 3)
        plan = _solve(c.cost_model(), lengths)
        cache.get(_bucket(plan), lambda: built.append(_bucket(plan)))
        seen.add(_bucket(plan))
        c.observe_step(step, plan,
                       estimate_plan_time(cost_model, plan), lengths)
        c.poll()
    # every executed bucket compiled exactly once, regardless of how many
    # times the controller re-planned into it
    assert sorted(set(built)) == sorted(seen)
    assert cache.stats.misses == len(seen)
    assert cache.stats.recompiles == 0
