"""Invariant tests for ``data/batching.py::materialize_chunks`` — the chunk
buffers the executor reads. The contracts under test are the module's own
conventions:

* ``targets`` are next-token ids across the WHOLE sequence: a non-tail
  slice's last token targets the next slice's first token;
* padding positions (and a tail's final token) carry ``seg = -1`` /
  ``target = -1``;
* ``pos`` is the position within the OWNING sequence — split slices
  continue from their context offset;
* ``ctx_len[k]`` equals the chunk's context length ``C_k`` (0 resets the
  context buffers / SSM state implicitly).
"""

import numpy as np
import pytest

from repro.core.plan import Chunk, ChunkKind, Slice
from repro.data.batching import materialize_chunks


def _split_seq_chunks(seq_id, length, cuts):
    """Chunks for one sequence split at ``cuts`` offsets (causal order)."""
    bounds = [0] + list(cuts) + [length]
    chunks = []
    for i in range(len(bounds) - 1):
        start, end = bounds[i], bounds[i + 1]
        is_tail = i == len(bounds) - 2
        sl = Slice(seq_id=seq_id, start=start, length=end - start,
                   is_tail=is_tail)
        chunks.append(Chunk(kind=ChunkKind.SPLIT, context=start,
                            slices=(sl,)))
    return chunks


def test_cross_slice_next_token_targets():
    """A non-tail slice's LAST token must target the NEXT slice's first
    token — the token-level-PP dependency the split-chunk KV carry exists
    for."""
    toks = np.arange(100, 110, dtype=np.int32)      # tokens are 100..109
    chunks = _split_seq_chunks(0, 10, cuts=(4, 8))  # slices [0,4) [4,8) [8,10)
    cb = materialize_chunks(chunks, {0: toks}, cap=8)
    # slice 0: tokens 100..103 target 101..104 — the last target (104) IS
    # the first token of slice 1
    np.testing.assert_array_equal(cb.tokens[0, :4], [100, 101, 102, 103])
    np.testing.assert_array_equal(cb.targets[0, :4], [101, 102, 103, 104])
    assert cb.targets[0, 3] == cb.tokens[1, 0]
    # slice 1 likewise crosses into slice 2
    np.testing.assert_array_equal(cb.targets[1, :4], [105, 106, 107, 108])
    assert cb.targets[1, 3] == cb.tokens[2, 0]
    # tail slice: last REAL token has no next token -> target -1
    np.testing.assert_array_equal(cb.tokens[2, :2], [108, 109])
    np.testing.assert_array_equal(cb.targets[2, :2], [109, -1])


def test_padding_is_fully_masked():
    """Beyond the packed tokens every position is seg = -1 / target = -1
    (the executor's CE mask and the bucket-padding contract)."""
    toks = {0: np.arange(6, dtype=np.int32),
            1: np.arange(50, 53, dtype=np.int32)}
    ch = Chunk(kind=ChunkKind.BATCHED, context=0,
               slices=(Slice(0, 0, 6, True), Slice(1, 0, 3, True)))
    cb = materialize_chunks([ch], toks, cap=16)
    used = 9
    np.testing.assert_array_equal(cb.seg[0, used:], -1)
    np.testing.assert_array_equal(cb.targets[0, used:], -1)
    np.testing.assert_array_equal(cb.tokens[0, used:], 0)
    np.testing.assert_array_equal(cb.pos[0, used:], 0)
    # packed slices get consecutive segment ids in pack order
    np.testing.assert_array_equal(cb.seg[0, :6], 0)
    np.testing.assert_array_equal(cb.seg[0, 6:9], 1)


def test_pos_continues_from_context_offset():
    """``pos`` is the within-sequence position: a split slice starting at
    offset C continues C, C+1, ... (RoPE/window masks depend on it)."""
    toks = np.arange(12, dtype=np.int32)
    chunks = _split_seq_chunks(0, 12, cuts=(5,))
    cb = materialize_chunks(chunks, {0: toks}, cap=8)
    np.testing.assert_array_equal(cb.pos[0, :5], np.arange(5))
    np.testing.assert_array_equal(cb.pos[1, :7], np.arange(5, 12))


def test_hybrid_chunk_pos_and_segments():
    """A hybrid chunk: tail slice (segment 0, pos continuing from its
    context) packed with shorts (segments 1.., pos restarting at 0)."""
    toks = {7: np.arange(40, dtype=np.int32),
            3: np.arange(200, 204, dtype=np.int32)}
    tail = Slice(seq_id=7, start=32, length=8, is_tail=True)
    short = Slice(seq_id=3, start=0, length=4, is_tail=True)
    ch = Chunk(kind=ChunkKind.HYBRID, context=32, slices=(tail, short))
    cb = materialize_chunks([ch], toks, cap=16)
    np.testing.assert_array_equal(cb.pos[0, :8], np.arange(32, 40))
    np.testing.assert_array_equal(cb.pos[0, 8:12], np.arange(4))
    np.testing.assert_array_equal(cb.seg[0, :8], 0)   # s0 IS segment 0
    np.testing.assert_array_equal(cb.seg[0, 8:12], 1)
    # the tail's last token ends the sequence; the short's last token too
    assert cb.targets[0, 7] == -1
    assert cb.targets[0, 11] == -1
    # non-final tokens still target the next token of their own sequence
    np.testing.assert_array_equal(cb.targets[0, :7], np.arange(33, 40))
    np.testing.assert_array_equal(cb.targets[0, 8:11], [201, 202, 203])


def test_ctx_len_semantics():
    """``ctx_len[k]`` = C_k: 0 for batched chunks and sequence starts
    (implicit buffer/SSM reset), the slice's start offset for split/hybrid
    chunks."""
    toks = {0: np.arange(20, dtype=np.int32),
            1: np.arange(60, 64, dtype=np.int32)}
    chunks = _split_seq_chunks(0, 20, cuts=(8, 14))
    batched = Chunk(kind=ChunkKind.BATCHED, context=0,
                    slices=(Slice(1, 0, 4, True),))
    cb = materialize_chunks(chunks + [batched], toks, cap=8)
    np.testing.assert_array_equal(cb.ctx_len, [0, 8, 14, 0])


def test_overflow_asserts():
    """A slice that does not fit the capacity is a materialization bug, not
    silent truncation."""
    toks = {0: np.arange(10, dtype=np.int32)}
    ch = Chunk(kind=ChunkKind.SPLIT, context=0,
               slices=(Slice(0, 0, 10, True),))
    with pytest.raises(AssertionError):
        materialize_chunks([ch], toks, cap=8)
