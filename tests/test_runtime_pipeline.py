"""Distributed-runtime equivalence tests.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest session keeps seeing exactly one CPU device (the dry-run
flag must never leak — see conftest.py). Each scenario script builds a tiny
arch on a (data=2, model=4) mesh, runs the shard_map'd EPP pipeline loss,
and compares against the single-device reference model on the same chunks.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.configs import get_arch
    from repro.core import ClusterSpec, CostModel, PlannerConfig, plan_batch
    from repro.data import materialize_plan, sample_corpus_batch
    from repro.models import DecoderLM, LayerCtx
    from repro.runtime import TrainStepBuilder, make_geometry
    from repro.runtime.pipeline import pipeline_loss_fn
    from repro.runtime.sharding import shard_dim_tree, mesh_axis_names, shard_map_compat
    from repro.runtime.train_step import prepare_params, param_pspecs, batch_specs, batch_struct

    def reference_loss(cfg, raw_params, chunks, corpus, cap, ctx_cap):
        model = DecoderLM(cfg)
        total, count = jnp.float32(0), jnp.float32(0)
        from repro.data.batching import materialize_chunks
        cb = materialize_chunks(chunks, corpus, cap)
        ctx = model.init_ctx(ctx_cap, jnp.float32)
        for k in range(cb.tokens.shape[0]):
            tok = jnp.asarray(cb.tokens[k]); tgt = jnp.asarray(cb.targets[k])
            sg = jnp.asarray(cb.seg[k]); ps = jnp.asarray(cb.pos[k])
            cl = int(cb.ctx_len[k])
            if cl == 0 and ctx.ssm_h is not None:
                ctx = ctx._replace(ssm_h=jnp.zeros_like(ctx.ssm_h))
            h, ctx = model.forward_chunk(raw_params, tok, sg, ps, ctx=ctx,
                                         ctx_len=cl, compute_dtype=jnp.float32)
            s, n = model.chunk_loss(raw_params, h, tgt, sg)
            total += s; count += n
        return total, count

    def run_case(arch, seed=0, n_seq=6, ctx_limit=192, fixed_k=2):
        cfg = get_arch(arch).reduced(n_layers=4, d_model=64, n_heads=4,
                                     head_dim=16, vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cm = CostModel(cfg.spec, ClusterSpec(d_p=2, d_s=4))
        rng = np.random.default_rng(seed)
        lens = [ctx_limit] + [int(x) for x in rng.integers(24, ctx_limit // 2, n_seq - 1)]
        corpus = {i: rng.integers(0, cfg.spec.vocab, l).astype(np.int32)
                  for i, l in enumerate(lens)}
        plan = plan_batch(cm, lens, PlannerConfig(fixed_k=fixed_k,
                                                  bucket_rounding=16))
        batch_np = materialize_plan(plan, corpus)
        chunks = [c for p in plan.pipelines for c in p.chunks]
        cap = plan.chunk_capacity
        # pad cap to a multiple of d_s for token sharding
        d_s = 4
        cap_pad = ((cap + d_s - 1)//d_s)*d_s
        import numpy as _np
        def padcap(a):
            if a.ndim == 2 and a.shape[1] == cap:
                out = _np.full((a.shape[0], cap_pad), -1 if a.dtype == _np.int32 else 0, a.dtype)
                out[:, :cap] = a
                if a is batch_np.tokens or a is batch_np.pos: out[:, cap:] = 0
                return out
            return a
        batch = {
            "tokens": _np.where(batch_np.seg >= 0, batch_np.tokens, 0),
            "targets": batch_np.targets, "seg": batch_np.seg,
            "pos": _np.where(batch_np.seg >= 0, batch_np.pos, 0),
            "ctx_len": batch_np.ctx_len}
        def pad2(a, fill):
            out = _np.full((a.shape[0], cap_pad), fill, a.dtype)
            out[:, :a.shape[1]] = a
            return out
        batch = {
            "tokens": pad2(batch["tokens"], 0),
            "targets": pad2(batch["targets"], -1),
            "seg": pad2(batch["seg"], -1),
            "pos": pad2(batch["pos"], 0),
            "ctx_len": batch["ctx_len"]}
        batch = {k: jnp.asarray(v) for k, v in batch.items()}

        ctx_cap = ctx_limit + cap_pad  # appends write cap rows at offset C_k
        geom = make_geometry(cfg, mesh, n_chunks=len(chunks), cap=cap_pad,
                             ctx_cap=ctx_cap, l_ckpt=0,
                             compute_dtype=jnp.float32)
        builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=jnp.float32)
        model = DecoderLM(cfg)
        raw = model.init(jax.random.PRNGKey(7), jnp.float32)
        params = prepare_params(cfg, raw, mesh, jnp.float32)
        pspecs, _, bspecs = builder.specs(jax.eval_shape(lambda: params))
        shard_dims = shard_dim_tree(params["stages"], 4)

        loss_fn = pipeline_loss_fn(cfg, geom, shard_dims, pod_axis=None)
        mapped = jax.jit(shard_map_compat(
            loss_fn, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(P(), P()), check_vma=False))
        loss_d, n_d = mapped(params, batch)

        loss_r, n_r = reference_loss(cfg, raw, chunks, corpus, cap_pad,
                                     ctx_cap)
        print("dist:", float(loss_d), float(n_d), " ref:", float(loss_r), float(n_r))
        assert int(n_d) == int(n_r), (n_d, n_r)
        rel = abs(float(loss_d) - float(loss_r)) / max(abs(float(loss_r)), 1e-9)
        assert rel < 2e-4, f"loss mismatch rel={rel}"
        print("OK", arch)
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _COMMON + case],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


@pytest.mark.parametrize("arch", ["llama3.2-3b",        # allgather_kv GQA
                                  "qwen3-4b",           # ulysses-capable
                                  "gemma3-1b",          # local:global, MQA
                                  "olmoe-1b-7b",        # MoE EP
                                  "deepseek-v2-lite",   # MLA + MoE
                                  "falcon-mamba-7b",    # SSM SP scan
                                  "hymba-1.5b"])        # hybrid
def test_pipeline_matches_reference(arch):
    _run(f"\nrun_case({arch!r})\n")


def test_pipeline_with_remat_matches():
    """l_ckpt > 0 must not change the loss (only the memory profile)."""
    _run(textwrap.dedent("""
        cfg = get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                              n_heads=4, head_dim=16,
                                              vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(3)
        lens = [160, 40, 30]
        corpus = {i: rng.integers(0, 256, l).astype(np.int32)
                  for i, l in enumerate(lens)}
        cm = CostModel(cfg.spec, ClusterSpec(d_p=2, d_s=4))
        plan = plan_batch(cm, lens, PlannerConfig(fixed_k=2, bucket_rounding=16))
        from repro.data import materialize_plan
        batch_np = materialize_plan(plan, corpus)
        cap = plan.chunk_capacity
        batch = {k: jnp.asarray(v) for k, v in batch_np.as_dict().items()}
        batch["tokens"] = jnp.where(batch["seg"] >= 0, batch["tokens"], 0)

        model = DecoderLM(cfg)
        raw = model.init(jax.random.PRNGKey(1), jnp.float32)
        params = prepare_params(cfg, raw, mesh, jnp.float32)
        shard_dims = shard_dim_tree(params["stages"], 4)
        losses = []
        n_chunks = sum(len(p.chunks) for p in plan.pipelines)
        for l_ckpt in (0, 1, 2):
            geom = make_geometry(cfg, mesh, n_chunks=n_chunks, cap=cap,
                                 ctx_cap=200, l_ckpt=l_ckpt,
                                 compute_dtype=jnp.float32)
            builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=jnp.float32)
            pspecs, _, bspecs = builder.specs(jax.eval_shape(lambda: params))
            loss_fn = pipeline_loss_fn(cfg, geom, shard_dims, pod_axis=None)
            mapped = jax.jit(shard_map_compat(
                loss_fn, mesh=mesh, in_specs=(pspecs, bspecs),
                out_specs=(P(), P()), check_vma=False))
            # also check gradients flow under remat
            def scalar(p):
                l, n = mapped(p, batch)
                return l / n
            val, grads = jax.value_and_grad(scalar)(params)
            losses.append(float(val))
            gleaves = jax.tree.leaves(grads)
            assert all(np.all(np.isfinite(np.asarray(g))) for g in gleaves)
        assert abs(losses[0] - losses[1]) < 1e-5
        assert abs(losses[0] - losses[2]) < 1e-5
        print("OK remat", losses)
    """))
