"""Planner + grouping integration tests."""

import math

import numpy as np
import pytest

from repro.core import (ClusterSpec, CostModel, ExecutionPlan, ModelSpec,
                        PlannerConfig, group_sequences, chunk_sequences,
                        plan_batch)


def test_plan_covers_all_tokens(cost_model, skewed_lengths):
    plan = plan_batch(cost_model, skewed_lengths)
    assert plan.total_tokens == sum(skewed_lengths)
    assert plan.n_chunks > 0
    assert plan.k_split >= 1
    assert plan.chunk_capacity >= max(c.tokens for p in plan.pipelines
                                      for c in p.chunks)
    assert plan.est_total_time > 0
    assert plan.solve_time > 0


def test_plan_schedules_filled(cost_model, skewed_lengths):
    plan = plan_batch(cost_model, skewed_lengths)
    for p in plan.pipelines:
        assert len(p.schedule) == cost_model.cluster.d_p
        assert len(p.ckpt) == cost_model.cluster.d_p
        assert all(len(row) == 2 * p.n_chunks for row in p.schedule)


def test_fixed_k_pins_split(cost_model, skewed_lengths):
    plan = plan_batch(cost_model, skewed_lengths, PlannerConfig(fixed_k=3))
    assert plan.k_split == 3


def test_ablations_run(cost_model, skewed_lengths):
    base = plan_batch(cost_model, skewed_lengths, PlannerConfig(fixed_k=4))
    nock = plan_batch(cost_model, skewed_lengths,
                      PlannerConfig(fixed_k=4, disable_ckpt=True))
    full = plan_batch(cost_model, skewed_lengths,
                      PlannerConfig(fixed_k=4, full_ckpt=True))
    wowbc = plan_batch(cost_model, skewed_lengths,
                       PlannerConfig(fixed_k=4, uniform_split=True))
    assert full.est_total_time >= base.est_total_time - 1e-9
    for p in nock.pipelines:
        assert all(v == 0 for row in p.ckpt for v in row)
    per_stage = cost_model.model.n_layers // cost_model.cluster.d_p
    for p in full.pipelines:
        assert all(v == per_stage for row in p.ckpt for v in row)
    assert wowbc.total_tokens == sum(skewed_lengths)


def test_grouping_splits_under_memory_pressure():
    """One gigantic sequence + many shorts with tight memory should produce
    more than one 1F1B pipeline (Fig. 5b) OR heavy checkpointing."""
    m = ModelSpec(name="t", n_layers=16, d_model=2048, n_heads=16,
                  n_kv_heads=8, head_dim=128, d_ff=8192, vocab=64000)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4, hbm_bytes=10e9))
    lengths = [262144] + [2048] * 60
    plan = plan_batch(cm, lengths)
    assert plan.total_tokens == sum(lengths)
    ckpt_layers = sum(sum(row) for p in plan.pipelines for row in p.ckpt)
    assert len(plan.pipelines) >= 2 or ckpt_layers > 0


def test_plan_serialization_roundtrip(cost_model, skewed_lengths):
    plan = plan_batch(cost_model, skewed_lengths, PlannerConfig(fixed_k=2))
    blob = plan.dumps()
    back = ExecutionPlan.loads(blob)
    assert back.k_split == plan.k_split
    assert back.n_chunks == plan.n_chunks
    assert back.total_tokens == plan.total_tokens
    assert [c.tokens for p in back.pipelines for c in p.chunks] == \
           [c.tokens for p in plan.pipelines for c in p.chunks]
    assert back.pipelines[0].schedule[0][0].op == \
           plan.pipelines[0].schedule[0][0].op


def test_straggler_replanning_rebalances():
    """With a slowed stage, the planner's estimate grows but stays feasible —
    the ft layer uses this loop for straggler mitigation."""
    m = ModelSpec(name="t", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                  head_dim=64, d_ff=2048, vocab=8192)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4))
    lengths = [16384] + [1024] * 24
    base = plan_batch(cm, lengths, PlannerConfig(fixed_k=3))
    slow = plan_batch(cm.with_slowdowns([1.0, 1.0, 1.8, 1.0]), lengths,
                      PlannerConfig(fixed_k=3))
    assert slow.est_total_time > base.est_total_time
