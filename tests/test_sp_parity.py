"""SP-axis parity gate: a plan's (policy, d_s_eff) must never change the math.

The planner now chooses the SP policy and effective degree per plan
(``ExecutionPlan.sp``); the runtime realizes sub-degrees as model-axis
sub-groups with replicated chunk compute. This suite pins the semantic
contract on the remat-parity harness pattern:

* for BOTH policies (ulysses, allgather_kv) at d_s_eff in {2, 4}, the
  training loss matches the unsharded baseline (policy "none" at
  d_s_eff=1) within float32 reduction-order noise and ``n_valid`` is
  EXACT (the replica CE mask counts every token exactly once);
* gradients agree to the repo's grad-parity standard (rtol=1e-6 /
  atol=1e-7);
* the contract composes with stage-aware remat tables and holds across
  schedule backends (gpipe-1f1b and the B/W-split zero-bubble-h1);
* prefill mode refuses sub-degree plans (the token-sharded greedy fold
  assumes distinct shards per device).

Runs in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest session keeps seeing one CPU device (see conftest.py).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.configs import get_arch
    from repro.models import DecoderLM
    from repro.runtime import TrainStepBuilder, make_geometry
    from repro.runtime.pipeline import pipeline_loss_fn
    from repro.runtime.sharding import shard_dim_tree, shard_map_compat
    from repro.runtime.train_step import prepare_params

    def sp_case(sp_policy=None, sp_degree=0, schedule="gpipe-1f1b",
                v_stages=1, l_ckpt=0, ckpt_table=None, mode="train"):
        # n_heads=8 => n_kv_heads=4 after reduction: ulysses legal at 2 AND 4
        cfg = get_arch("llama3.2-3b").reduced(n_layers=4, d_model=64,
                                              n_heads=8, head_dim=16,
                                              vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, cap = 4, 32
        rng = np.random.default_rng(0)
        seg = np.repeat(np.arange(n, dtype=np.int32)[:, None], cap, 1)
        seg[:, -3:] = -1  # ragged tail: padding the CE mask must skip
        batch = {
            "tokens": rng.integers(0, 256, (n, cap)).astype(np.int32),
            "targets": rng.integers(0, 256, (n, cap)).astype(np.int32),
            "seg": seg,
            "pos": np.tile(np.arange(cap, dtype=np.int32), (n, 1)),
            "ctx_len": np.zeros((n,), np.int32),
        }
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        geom = make_geometry(cfg, mesh, n_chunks=n, cap=cap, ctx_cap=2 * cap,
                             l_ckpt=l_ckpt, compute_dtype=jnp.float32,
                             schedule=schedule, v_stages=v_stages,
                             ckpt_table=ckpt_table,
                             sp_policy=sp_policy, sp_degree=sp_degree)
        builder = TrainStepBuilder(cfg, mesh, geom, param_dtype=jnp.float32)
        raw = DecoderLM(cfg).init(jax.random.PRNGKey(7), jnp.float32)
        params = prepare_params(cfg, raw, mesh, jnp.float32,
                                v_stages=v_stages)
        pspecs, _, bspecs = builder.specs(jax.eval_shape(lambda: params))
        sd = shard_dim_tree(params["stages"], 4)
        loss = pipeline_loss_fn(cfg, geom, sd, pod_axis=None, mode=mode)
        fn = jax.jit(shard_map_compat(
            loss, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(P(), P()), check_vma=False))
        return fn, params, batch

    def loss_and_grads(fn, params, batch):
        def scalar(p):
            l, n = fn(p, batch)
            return l / n
        l, nv = fn(params, batch)
        g = jax.grad(scalar)(params)
        return (np.asarray(l), float(nv),
                [np.asarray(x) for x in jax.tree.leaves(g)])

    def check_sp_parity(results, tag, base="none@1"):
        l0, n0, g0 = results[base]
        for name, (l, n, g) in results.items():
            assert n == n0, (tag, name, n, n0)
            np.testing.assert_allclose(
                l, l0, rtol=1e-6, atol=0,
                err_msg=f"{tag}/{name}: loss drifted across SP points")
            for a, b in zip(g, g0):
                np.testing.assert_allclose(
                    a, b, rtol=1e-6, atol=1e-7,
                    err_msg=f"{tag}/{name}: grads drifted across SP points")
""")


def _run(case: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _COMMON + textwrap.dedent(case)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}")
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# both policies x sub-degrees vs the unsharded baseline, two schedules
# ---------------------------------------------------------------------------

SP_POINTS = [("none", 1), ("ulysses", 2), ("ulysses", 4),
             ("allgather_kv", 2), ("allgather_kv", 4)]


def test_sp_parity_gpipe():
    _run("""
        results = {}
        for policy, d in [("none", 1), ("ulysses", 2), ("ulysses", 4),
                          ("allgather_kv", 2), ("allgather_kv", 4)]:
            fn, params, batch = sp_case(sp_policy=policy, sp_degree=d)
            results[f"{policy}@{d}"] = loss_and_grads(fn, params, batch)
        check_sp_parity(results, "sp/gpipe-1f1b")
        print("OK sp parity gpipe", float(results["none@1"][0]))
    """)


def test_sp_parity_zero_bubble():
    _run("""
        results = {}
        for policy, d in [("none", 1), ("ulysses", 4),
                          ("allgather_kv", 2)]:
            fn, params, batch = sp_case(sp_policy=policy, sp_degree=d,
                                        schedule="zero-bubble-h1")
            results[f"{policy}@{d}"] = loss_and_grads(fn, params, batch)
        check_sp_parity(results, "sp/zero-bubble-h1")
        print("OK sp parity zero-bubble", float(results["none@1"][0]))
    """)


def test_sp_parity_composed_with_stage_aware_remat():
    _run("""
        TAB = ((2, 0, 1, 2), (1, 2, 0, 0))
        results = {}
        for policy, d in [("none", 1), ("ulysses", 4),
                          ("allgather_kv", 2)]:
            fn, params, batch = sp_case(sp_policy=policy, sp_degree=d,
                                        l_ckpt=2, ckpt_table=TAB)
            results[f"{policy}@{d}"] = loss_and_grads(fn, params, batch)
        check_sp_parity(results, "sp/remat-vector")
        print("OK sp parity with stage-aware remat")
    """)


def test_sp_full_degree_default_unchanged():
    """make_geometry with no SP args (the legacy call) must equal an
    explicit full-degree pin — old callers keep bitwise-identical plans."""
    _run("""
        fa, pa, ba = sp_case()                      # legacy default
        fb, pb, bb = sp_case(sp_policy="ulysses", sp_degree=4)
        la, na, ga = loss_and_grads(fa, pa, ba)
        lb, nb, gb = loss_and_grads(fb, pb, bb)
        assert na == nb
        assert la.tobytes() == lb.tobytes(), (float(la), float(lb))
        for a, b in zip(ga, gb):
            assert a.tobytes() == b.tobytes(), \\
                "explicit full-degree pin drifted from the legacy default"
        print("OK legacy default == full-degree pin", float(la))
    """)


# ---------------------------------------------------------------------------
# guard rails (no compile needed)
# ---------------------------------------------------------------------------

def test_prefill_rejects_sub_degree():
    _run("""
        fn, params, batch = None, None, None
        try:
            sp_case(sp_policy="allgather_kv", sp_degree=2, mode="prefill")
        except ValueError as e:
            assert "d_s_eff == d_s" in str(e), e
            print("OK prefill rejects sub-degree")
        else:
            raise AssertionError("prefill accepted d_s_eff < d_s")
    """)


def test_geometry_validation():
    from repro.runtime.pipeline import PipelineGeometry

    common = dict(n_chunks=2, cap=32, ctx_cap=64, d_p=2, d_s=4, l_ckpt=0,
                  layers_per_stage=2)
    with pytest.raises(ValueError, match="divide"):
        PipelineGeometry(policy="allgather_kv", d_s_eff=3, **common)
    with pytest.raises(ValueError, match="ulysses"):
        PipelineGeometry(policy="ulysses", d_s_eff=1, **common)
    g = PipelineGeometry(policy="allgather_kv", d_s_eff=2, **common)
    assert g.sp_rep == 2
    # legacy default: d_s_eff=0 resolves to the full axis
    g2 = PipelineGeometry(policy="ulysses", **common)
    assert g2.d_s_eff == 4 and g2.sp_rep == 1
