"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
``launch/dryrun.py`` requests 512 placeholder devices (and only in its own
process)."""

import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Optional-import shim for ``hypothesis``: several test modules use
# property-based tests (@given/@settings + strategies). On a bare interpreter
# without hypothesis installed, collection must still succeed — install a
# stub module whose @given decorator turns each property test into a skip.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(property-based case skipped)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder: strategy expressions build but never draw."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "lists", "floats", "booleans", "sampled_from",
                  "tuples", "text", "composite", "just", "one_of",
                  "dictionaries"):
        setattr(_st, _name, _Strategy())

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None,
                                             filter_too_much=None)
    _hyp.assume = lambda *_a, **_k: True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

from repro.core import ClusterSpec, CostModel, ModelSpec


@pytest.fixture
def tiny_model() -> ModelSpec:
    return ModelSpec(name="tiny", n_layers=8, d_model=256, n_heads=8,
                     n_kv_heads=4, head_dim=32, d_ff=1024, vocab=512)


@pytest.fixture
def small_cluster() -> ClusterSpec:
    return ClusterSpec(d_p=4, d_s=4, flops_per_chip=197e12, hbm_bytes=16e9)


@pytest.fixture
def cost_model(tiny_model, small_cluster) -> CostModel:
    return CostModel(tiny_model, small_cluster)


@pytest.fixture
def skewed_lengths():
    rng = np.random.default_rng(42)
    lens = np.clip(rng.lognormal(7.5, 1.1, 48).astype(int), 64, 65536)
    lens[0] = 65536
    return [int(x) for x in lens]
