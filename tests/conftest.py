"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
``launch/dryrun.py`` requests 512 placeholder devices (and only in its own
process)."""

import numpy as np
import pytest

from repro.core import ClusterSpec, CostModel, ModelSpec


@pytest.fixture
def tiny_model() -> ModelSpec:
    return ModelSpec(name="tiny", n_layers=8, d_model=256, n_heads=8,
                     n_kv_heads=4, head_dim=32, d_ff=1024, vocab=512)


@pytest.fixture
def small_cluster() -> ClusterSpec:
    return ClusterSpec(d_p=4, d_s=4, flops_per_chip=197e12, hbm_bytes=16e9)


@pytest.fixture
def cost_model(tiny_model, small_cluster) -> CostModel:
    return CostModel(tiny_model, small_cluster)


@pytest.fixture
def skewed_lengths():
    rng = np.random.default_rng(42)
    lens = np.clip(rng.lognormal(7.5, 1.1, 48).astype(int), 64, 65536)
    lens[0] = 65536
    return [int(x) for x in lens]
