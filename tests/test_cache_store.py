"""Persistent compile-cache store: warm-start round-trip across simulated
process restarts, fingerprint invalidation, corruption fallback, and the
warm-hit / cost-aware-eviction accounting in CompileCache.

The store contract under test (runtime/cache_store.py):
* a fresh cache in a "new process" warm-loads a persisted executable and
  produces BITWISE-identical outputs to the cold compile;
* a stale fingerprint (topology/config change) or a corrupted payload is
  SKIPPED — cold compile fallback, never a wrong load, never a crash.
"""

import json
import pickle

import numpy as np
import pytest

from repro.runtime.cache_store import (CacheStore, model_fingerprint,
                                       store_fingerprint)
from repro.runtime.compile_cache import CompileCache, global_cache_stats, \
    reset_global_caches


# ---------------------------------------------------------------------------
# jax-free unit tests: store bookkeeping + CompileCache integration
# ---------------------------------------------------------------------------

class _FakeStore:
    """In-memory stand-in implementing the CompileCache store protocol."""

    def __init__(self, preload=None):
        self.blobs = dict(preload or {})
        self.saved = {}

    def load(self, key):
        return self.blobs.get(key)

    def save(self, key, value, *, compile_seconds=0.0):
        self.saved[key] = (value, compile_seconds)
        self.blobs[key] = value
        return True


def test_warm_hit_accounting():
    """A store hit is a warm hit — not a plain hit, not a cold compile —
    and the build callable must NOT run."""
    cache = CompileCache(name="warm", store=_FakeStore({("k",): "warm!"}))
    built = []
    v = cache.get(("k",), lambda: built.append(1) or "cold")
    assert v == "warm!" and built == []
    s = cache.stats
    assert (s.warm_hits, s.misses, s.hits) == (1, 0, 0)
    assert s.lookups == 1
    assert s.compile_seconds == 0.0
    # now resident: second lookup is a plain in-memory hit
    assert cache.get(("k",), lambda: "cold") == "warm!"
    assert cache.stats.hits == 1
    d = s.as_dict()
    assert d["warm_hits"] == 1 and "warm_hits" in s.summary()


def test_cold_compile_offered_to_store():
    store = _FakeStore()
    cache = CompileCache(name="offer", store=store)
    cache.get("key", lambda: "artifact")
    assert store.saved["key"][0] == "artifact"
    assert cache.stats.misses == 1 and cache.stats.warm_hits == 0


def test_cost_aware_eviction_drops_cheap_buckets_first():
    cache = CompileCache(name="cost", capacity=2, eviction="cost")
    cache.get("slow", lambda: "s")
    cache.get("fast", lambda: "f")
    # make the recorded rebuild costs unambiguous
    cache.stats.compile_seconds_per_key[repr("slow")] = 30.0
    cache.stats.compile_seconds_per_key[repr("fast")] = 0.1
    cache.get("new", lambda: "n")
    # plain LRU would evict "slow" (oldest); cost-aware keeps it
    assert "slow" in cache and "new" in cache and "fast" not in cache
    assert cache.stats.evictions == 1
    assert repr("fast") not in cache.stats.compile_seconds_per_key


def test_cost_eviction_never_drops_just_inserted_entry():
    cache = CompileCache(name="cost2", capacity=1, eviction="cost")
    cache.get("a", lambda: "a")
    cache.stats.compile_seconds_per_key[repr("a")] = 100.0
    cache.get("b", lambda: "b")  # b is newest: a must go despite its cost
    assert "b" in cache and "a" not in cache


def test_clear_is_observable_in_stats():
    """clear(reset_stats=False) must not make resident executables vanish
    invisibly: the dropped count lands in ``cleared`` and flows through
    as_dict + global_cache_stats."""
    reset_global_caches()
    cache = CompileCache(name="clear-obs")
    cache.get(1, lambda: "x")
    cache.get(2, lambda: "y")
    cache.clear()
    assert cache.stats.cleared == 2
    assert cache.stats.buckets_live == 0
    assert cache.stats.compile_seconds_per_key == {}
    d = cache.stats.as_dict()
    assert d["cleared"] == 2
    g = global_cache_stats()
    assert g["cleared"] == 2 and g["caches"]["clear-obs"]["cleared"] == 2
    # a second clear with nothing resident adds nothing
    cache.clear()
    assert cache.stats.cleared == 2
    # reset_stats zeroes the counter with everything else
    cache.get(3, lambda: "z")
    cache.clear(reset_stats=True)
    assert cache.stats.cleared == 0


def test_model_fingerprint_tracks_spec_fields():
    from repro.core import ModelSpec
    a = ModelSpec(name="t", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=64)
    b = ModelSpec(name="t", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=64)
    c = ModelSpec(name="t", n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=64)
    assert model_fingerprint(a) == model_fingerprint(b)
    assert model_fingerprint(a) != model_fingerprint(c)


def test_store_save_of_unserializable_artifact_degrades(tmp_path):
    """A jit wrapper (not a Compiled) or a plain value cannot be
    serialized: save must return False and count, never raise."""
    store = CacheStore(tmp_path, {"v": 1})
    ok = store.save(("k",), object())
    assert not ok
    assert store.stats.save_errors == 1
    assert store.load(("k",)) is None
    assert store.report()["entries"] == 0


# ---------------------------------------------------------------------------
# jax round-trip tests: serialize -> "new process" -> deserialize
# ---------------------------------------------------------------------------

def _compile_toy_step(scale: float):
    """A tiny AOT-compiled jit step standing in for a bucket executable."""
    import jax
    import jax.numpy as jnp

    def step(x):
        return jnp.tanh(x * scale) @ jnp.full((8, 8), scale, jnp.float32)

    x_abs = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    return jax.jit(step).lower(x_abs).compile()


def _toy_input():
    import jax.numpy as jnp
    return jnp.linspace(-2.0, 2.0, 64, dtype=jnp.float32).reshape(8, 8)


def test_warm_start_round_trip_bitwise_identical(tmp_path):
    """Populate the store in "process 1"; a fresh CompileCache + CacheStore
    in "process 2" must warm-load (0 fresh compiles) and produce output
    bitwise-identical to the cold compile."""
    fp = store_fingerprint()
    key = ("bucket", 8, 128)

    # --- process 1: cold compile, persisted ---
    store1 = CacheStore(tmp_path, fp)
    cache1 = CompileCache(name="proc1", store=store1)
    compiled1 = cache1.get(key, lambda: _compile_toy_step(0.5))
    assert cache1.stats.misses == 1 and store1.stats.saves == 1
    cold_out = np.asarray(compiled1(_toy_input()))

    # --- process 2: fresh cache + store objects over the same directory ---
    store2 = CacheStore(tmp_path, store_fingerprint())
    cache2 = CompileCache(name="proc2", store=store2)
    built = []
    compiled2 = cache2.get(key, lambda: built.append(1) or
                           _compile_toy_step(0.5))
    assert built == [], "warm start must not compile"
    assert cache2.stats.warm_hits == 1 and cache2.stats.misses == 0
    assert cache2.stats.compile_seconds == 0.0
    warm_out = np.asarray(compiled2(_toy_input()))
    assert cold_out.tobytes() == warm_out.tobytes()


def test_stale_fingerprint_skipped_with_cold_fallback(tmp_path):
    """A topology change (different fingerprint) must not load the old
    entry: stale skip + cold compile, and the old entry survives for a
    process that returns to the original topology (elastic grow-back)."""
    fp_a = store_fingerprint(extra={"mesh": [["data", 2], ["model", 2]]})
    fp_b = store_fingerprint(extra={"mesh": [["data", 1], ["model", 2]]})
    key = ("bucket", 1)

    store_a = CacheStore(tmp_path, fp_a)
    CompileCache(name="a", store=store_a).get(
        key, lambda: _compile_toy_step(1.0))
    assert store_a.stats.saves == 1

    store_b = CacheStore(tmp_path, fp_b)
    cache_b = CompileCache(name="b", store=store_b)
    built = []
    cache_b.get(key, lambda: built.append(1) or _compile_toy_step(2.0))
    assert built == [1], "stale entry must cold compile"
    assert store_b.stats.stale_skips == 1
    assert cache_b.stats.warm_hits == 0 and cache_b.stats.misses == 1

    # both topologies' entries now coexist; returning to fp_a warm-starts
    store_a2 = CacheStore(tmp_path, fp_a)
    cache_a2 = CompileCache(name="a2", store=store_a2)
    cache_a2.get(key, lambda: pytest.fail("should warm-start"))
    assert cache_a2.stats.warm_hits == 1
    assert store_a2.report()["entries"] == 2


def test_fingerprint_with_non_json_native_values_round_trips(tmp_path):
    """Tuples and arbitrary objects in the fingerprint must not (a) crash
    save()'s sidecar dump or (b) read back permanently stale because the
    JSON round-trip changed their representation — the fingerprint is
    canonicalized once at construction."""
    class Odd:
        def __str__(self):
            return "odd-value"

    fp = {"mesh": (("data", 2), ("model", 2)), "dtype": Odd()}
    key = ("bucket", 9)
    store1 = CacheStore(tmp_path, fp)
    cache1 = CompileCache(name="nj1", store=store1)
    cache1.get(key, lambda: _compile_toy_step(0.9))
    assert store1.stats.saves == 1 and store1.stats.save_errors == 0

    # "new process": an equal-but-distinct fingerprint object
    store2 = CacheStore(tmp_path, {"mesh": (("data", 2), ("model", 2)),
                                   "dtype": Odd()})
    cache2 = CompileCache(name="nj2", store=store2)
    cache2.get(key, lambda: pytest.fail("should warm-start"))
    assert cache2.stats.warm_hits == 1
    assert store2.stats.stale_skips == 0


def test_corrupted_payload_skipped_with_cold_fallback(tmp_path):
    fp = store_fingerprint()
    key = ("bucket", 2)
    store1 = CacheStore(tmp_path, fp)
    CompileCache(name="c1", store=store1).get(
        key, lambda: _compile_toy_step(1.5))
    (bin_path,) = tmp_path.glob("*.bin")
    bin_path.write_bytes(bin_path.read_bytes()[:-16] + b"garbagegarbage!!")

    store2 = CacheStore(tmp_path, fp)
    cache2 = CompileCache(name="c2", store=store2)
    built = []
    out = cache2.get(key, lambda: built.append(1) or _compile_toy_step(1.5))
    assert built == [1], "corrupted entry must cold compile"
    assert store2.stats.corrupt_skips == 1
    assert cache2.stats.misses == 1 and cache2.stats.warm_hits == 0
    # the fallback still works as an executable
    assert np.isfinite(np.asarray(out(_toy_input()))).all()


def test_unreadable_sidecar_skipped(tmp_path):
    fp = store_fingerprint()
    key = ("bucket", 3)
    store1 = CacheStore(tmp_path, fp)
    CompileCache(name="s1", store=store1).get(
        key, lambda: _compile_toy_step(0.3))
    (meta_path,) = tmp_path.glob("*.meta.json")
    meta_path.write_text("{not json")
    store2 = CacheStore(tmp_path, fp)
    assert store2.load(key) is None
    assert store2.stats.corrupt_skips == 1


def test_undeserializable_blob_counts_load_error(tmp_path):
    """A well-formed entry whose payload is not a serialized executable
    (e.g. written by a different library version) falls back cleanly."""
    fp = store_fingerprint()
    key = ("bucket", 4)
    store = CacheStore(tmp_path, fp)
    # hand-craft an entry whose sha checks out but whose pickle payload
    # is not a (payload, in_tree, out_tree) triple
    blob = pickle.dumps("not an executable")
    bin_path, meta_path = store._paths(key)
    bin_path.write_bytes(blob)
    import hashlib
    meta_path.write_text(json.dumps({
        "fingerprint": fp, "key": repr(key),
        "payload_sha": hashlib.sha256(blob).hexdigest(),
        "payload_bytes": len(blob), "compile_seconds": 0, "created": 0}))
    assert store.load(key) is None
    assert store.stats.load_errors == 1


def test_global_stats_carry_store_report(tmp_path):
    reset_global_caches()
    store = CacheStore(tmp_path, store_fingerprint())
    cache = CompileCache(name="with-store", store=store)
    cache.get(("k",), lambda: _compile_toy_step(0.7))
    g = global_cache_stats()
    blk = g["caches"]["with-store"]["store"]
    assert blk["entries"] == 1 and blk["saves"] == 1
    assert blk["size_bytes"] > 0
    assert blk["entries_current_fingerprint"] == 1


# ---------------------------------------------------------------------------
# end-to-end: a second train() run against a populated cache dir compiles
# 0 fresh executables and reproduces the cold run's losses bitwise
# ---------------------------------------------------------------------------

def test_train_warm_start_end_to_end(tmp_path):
    import jax

    from repro.configs import get_arch
    from repro.launch.train import TrainLoopConfig, train

    cfg = get_arch("gemma3-1b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mk = lambda: TrainLoopConfig(steps=2, global_batch=2, context=128,
                                 cache_dir=str(tmp_path / "cc"),
                                 compute_dtype="float32")

    _, _, hist_cold = train(cfg, mesh, mk(), log=lambda *_: None)
    cc = hist_cold[-1]["compile_cache"]
    assert cc["misses"] >= 1 and cc["warm_hits"] == 0
    assert hist_cold[-1]["cache_store"]["saves"] >= 1

    _, _, hist_warm = train(cfg, mesh, mk(), log=lambda *_: None)
    cc = hist_warm[-1]["compile_cache"]
    assert cc["misses"] == 0, f"warm run recompiled: {cc}"
    assert cc["warm_hits"] >= 1
    assert cc["compile_seconds"] == 0.0
    # warm-loaded executables reproduce the cold run bitwise
    cold = [(h["step"], h["loss"]) for h in hist_cold]
    warm = [(h["step"], h["loss"]) for h in hist_warm]
    assert cold == warm


# ---------------------------------------------------------------------------
# Bucket-key collision regression: remat vectors are part of the compiled
# step's identity — two plans that differ ONLY in their checkpointing
# vector must land in different buckets and different store entries.
# ---------------------------------------------------------------------------

def _plans_differing_only_in_ckpt():
    import copy

    from repro.core import ClusterSpec, CostModel, ModelSpec, \
        PlannerConfig, plan_batch

    m = ModelSpec(name="t", n_layers=16, d_model=1024, n_heads=16,
                  n_kv_heads=8, head_dim=64, d_ff=4096, vocab=32000)
    cm = CostModel(m, ClusterSpec(d_p=4, d_s=4, hbm_bytes=16e9))
    lengths = [65536, 30000, 8000, 8000, 4000, 2000, 1000, 500]
    plan_a = plan_batch(cm, lengths, PlannerConfig(
        bucket_rounding=64, remat_mode="stage_aware",
        capacity_bytes=cm.cluster.hbm_bytes * 0.1))
    assert plan_a.uniform_ckpt() > 0, "fixture must force checkpointing"
    plan_b = copy.deepcopy(plan_a)
    # same chunks, same schedule, same geometry — one remat entry moved
    tab = plan_b.pipelines[0].ckpt
    p, k = next((p, k) for p in range(len(tab))
                for k in range(len(tab[p])) if tab[p][k] > 0)
    tab[p][k] -= 1
    return plan_a, plan_b


def test_bucket_key_distinguishes_ckpt_vectors():
    plan_a, plan_b = _plans_differing_only_in_ckpt()
    ka, kb = plan_a.bucket_key(4), plan_b.bucket_key(4)
    # identical geometry/schedule tail ...
    assert ka._replace(ckpt="", l_ckpt=0) == kb._replace(ckpt="", l_ckpt=0)
    # ... but distinct remat digests => distinct bucket identities
    assert ka.ckpt != kb.ckpt
    assert ka != kb
    # and a CompileCache treats them as separate buckets (no false hit)
    cache = CompileCache(name="ckpt-buckets")
    assert cache.get(ka, lambda: "A") == "A"
    assert cache.get(kb, lambda: "B") == "B"
    assert cache.stats.hits == 0 and cache.stats.misses == 2


def test_cache_store_keeps_ckpt_vectors_apart(tmp_path):
    """No warm-hit on a wrong-remat executable: entries persisted under
    the two keys coexist on disk, and each key loads back exactly its own
    executable (distinguishable outputs prove which one ran)."""
    plan_a, plan_b = _plans_differing_only_in_ckpt()
    ka, kb = plan_a.bucket_key(4), plan_b.bucket_key(4)
    fp = store_fingerprint()

    store1 = CacheStore(tmp_path, fp)
    cache1 = CompileCache(name="ckpt-proc1", store=store1)
    out_a = np.asarray(cache1.get(
        ka, lambda: _compile_toy_step(0.5))(_toy_input()))
    out_b = np.asarray(cache1.get(
        kb, lambda: _compile_toy_step(2.0))(_toy_input()))
    assert store1.stats.saves == 2
    assert len(list(tmp_path.glob("*.bin"))) == 2, \
        "ckpt-vector variants must not overwrite each other's entries"
    assert out_a.tobytes() != out_b.tobytes()

    # "restart": each key warm-loads its OWN executable
    store2 = CacheStore(tmp_path, store_fingerprint())
    cache2 = CompileCache(name="ckpt-proc2", store=store2)
    warm_a = np.asarray(cache2.get(
        ka, lambda: pytest.fail("must warm-load"))(_toy_input()))
    warm_b = np.asarray(cache2.get(
        kb, lambda: pytest.fail("must warm-load"))(_toy_input()))
    assert cache2.stats.warm_hits == 2 and cache2.stats.misses == 0
    assert warm_a.tobytes() == out_a.tobytes()
    assert warm_b.tobytes() == out_b.tobytes()


# ---------------------------------------------------------------------------
# gc(): age / size-budget eviction, least-recently-loaded first
# ---------------------------------------------------------------------------

def test_gc_noop_without_limits(tmp_path):
    store = CacheStore(tmp_path, {"v": 1})
    CompileCache(name="gc0", store=store).get(
        ("k",), lambda: _compile_toy_step(1.0))
    rep = store.gc()
    assert rep["removed"] == 0 and store.report()["entries"] == 1
    assert store.stats.gc_removed == 0


def test_gc_age_evicts_old_entries_only(tmp_path):
    import os
    import time

    store = CacheStore(tmp_path, {"v": 1})
    cache = CompileCache(name="gca", store=store)
    cache.get(("old",), lambda: _compile_toy_step(1.0))
    cache.get(("new",), lambda: _compile_toy_step(2.0))
    # age the first entry far past the cutoff
    old_bin = next(p for p in tmp_path.glob("*.bin")
                   if json.loads(p.with_name(
                       p.name[:-4] + ".meta.json").read_text())["key"]
                   == repr(("old",)))
    past = time.time() - 1000
    os.utime(old_bin, (past, past))
    rep = store.gc(max_age_s=100)
    assert rep["removed"] == 1
    assert store.stats.gc_removed == 1 and store.stats.gc_removed_bytes > 0
    # the aged entry is a plain miss now; the fresh one still loads
    fresh = CompileCache(name="gca2", store=CacheStore(tmp_path, {"v": 1}))
    built = []
    fresh.get(("old",), lambda: built.append(1) or _compile_toy_step(1.0))
    fresh.get(("new",), lambda: built.append(2) or _compile_toy_step(2.0))
    assert built == [1], "old must cold-compile, new must warm-start"


def test_gc_size_budget_keeps_recently_loaded(tmp_path):
    import os
    import time

    store = CacheStore(tmp_path, {"v": 1})
    cache = CompileCache(name="gcs", store=store)
    cache.get(("a",), lambda: _compile_toy_step(1.0))
    cache.get(("b",), lambda: _compile_toy_step(2.0))
    # stamp distinct mtimes, then LOAD "a" through a fresh store — the
    # load-touch must protect it from the size-budget eviction
    for i, p in enumerate(sorted(tmp_path.glob("*.bin"))):
        os.utime(p, (time.time() - 500 + i, time.time() - 500 + i))
    store2 = CacheStore(tmp_path, {"v": 1})
    assert CompileCache(name="gcs2", store=store2).get(
        ("a",), lambda: pytest.fail("should warm-start")) is not None
    one_entry = max(p.stat().st_size for p in tmp_path.glob("*.bin"))
    rep = store2.gc(max_bytes=one_entry)
    assert rep["removed"] == 1
    assert rep["remaining_bytes"] <= one_entry
    # survivor is the recently-loaded "a"
    store3 = CacheStore(tmp_path, {"v": 1})
    cache3 = CompileCache(name="gcs3", store=store3)
    built = []
    cache3.get(("a",), lambda: built.append("a") or _compile_toy_step(1.0))
    cache3.get(("b",), lambda: built.append("b") or _compile_toy_step(2.0))
    assert built == ["b"], built


def test_gc_removal_is_miss_not_stale(tmp_path):
    """A gc'd entry must read as a plain miss — not a misleading stale or
    corrupt skip (the .bin goes first, orphan sidecars are ignored)."""
    store = CacheStore(tmp_path, {"v": 1})
    CompileCache(name="gcm", store=store).get(
        ("k",), lambda: _compile_toy_step(1.0))
    assert store.gc(max_age_s=0)["removed"] == 1
    store2 = CacheStore(tmp_path, {"v": 1})
    assert store2.load(("k",)) is None
    assert store2.stats.stale_skips == 0 and store2.stats.corrupt_skips == 0
