"""Substrate tests: checkpoint/restore, gradient compression, straggler
monitor, data pipeline, optimizer."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.data import materialize_chunks, sample_corpus_batch, sample_lengths
from repro.ft import StragglerMonitor
from repro.optim import (AdamWConfig, adamw_update, compressed_psum,
                         init_opt_state)


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True, keep=2)
        for step in (1, 2, 3):
            t = jax.tree.map(lambda x: x + step, tree)
            mgr.save(step, t, extra={"step": step})
        mgr.wait()
        assert mgr.latest_step() == 3
        restored, extra = mgr.restore(tree)
        assert extra["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]) + 3)
        # gc kept only the last 2
        mgr2 = CheckpointManager(d)
        with pytest.raises(Exception):
            mgr2.restore(tree, step=1)


def test_checkpoint_rejects_shape_mismatch():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(0, {"a": jnp.ones((3,))})
        with pytest.raises(ValueError):
            mgr.restore({"a": jnp.ones((4,))})


def test_compression_error_feedback_converges():
    """Quantized psum with error feedback: averaged over steps the bias
    vanishes (residual carried forward)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    total_q = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        # single-device psum == identity; quantization still applies
        out, err = jax.jit(
            lambda gg, ee: compressed_psum({"g": gg}, {"g": ee}, None)
            if False else _one(gg, ee))(g, err)
        total_q = total_q + out
    avg = total_q / steps
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g),
                               rtol=0, atol=2e-2)


def _one(g, e):
    from repro.optim.compression import _q8_psum

    # emulate psum over a single-axis group of size 1 via direct math
    g32 = g + e
    scale = jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127)
    return q * scale, g32 - q * scale


def test_straggler_monitor_flags_and_clears():
    mon = StragglerMonitor(d_p=4, ewma=1.0)
    mon.observe([1.0, 1.0, 1.0, 1.0])
    assert mon.slowdowns() is None
    mon.observe([1.0, 1.0, 1.9, 1.0])
    s = mon.slowdowns()
    assert s is not None and s[2] > 1.5 and s[0] == 1.0


def test_sample_lengths_skewed():
    lens = sample_lengths("github", 512, 98304, seed=1)
    assert max(lens) == 98304           # long tail pinned to the limit
    assert np.median(lens) < 98304 / 8  # heavy skew
    assert min(lens) >= 64


def test_materialize_targets_cross_slices():
    """Next-token targets must cross split-chunk slice boundaries."""
    from repro.core.plan import Chunk, ChunkKind, Slice
    toks = np.arange(100, dtype=np.int32)
    chunks = [
        Chunk(ChunkKind.SPLIT, 0, (Slice(0, 0, 60, False),)),
        Chunk(ChunkKind.SPLIT, 60, (Slice(0, 60, 40, True),)),
    ]
    cb = materialize_chunks(chunks, {0: toks}, cap=64)
    # last token of slice 1 predicts first token of slice 2
    assert cb.targets[0][59] == 60
    assert cb.targets[1][39] == -1      # sequence end: ignored
    assert cb.ctx_len[1] == 60
    assert cb.pos[1][0] == 60


def test_adamw_updates_and_decays():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = init_opt_state(params)
    grads = {"w": jnp.full((8,), 0.5, jnp.float32)}
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, grad_clip=10.0)
    p2, s2, m = adamw_update(cfg, params, grads, state,
                             grad_scale=jnp.float32(1.0))
    assert float(s2["step"]) == 1
    assert np.all(np.asarray(p2["w"], np.float32) < 1.0)  # moved downhill
    assert m["grad_norm"] > 0


def test_latest_step_ignores_stray_step_dirs():
    """Discovery must skip unparseable ``step_*`` names — an editor backup,
    a future ``step_tmp`` scratch dir, or a crashed save's
    ``step_xxx.tmp`` (which can already CONTAIN a manifest, since the
    manifest is written before the atomic rename) — instead of crashing
    with ValueError."""
    import os

    tree = {"a": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(7, tree, extra={"step": 7})
        # stray dirs a real filesystem accumulates:
        for stray in ("step_tmp", "step_00000003.bak",
                      "step_00000009.tmp"):
            os.makedirs(os.path.join(d, stray))
            with open(os.path.join(d, stray, "manifest.json"), "w") as f:
                f.write("{}")
        assert mgr.latest_step() == 7
        restored, extra = mgr.restore(tree)
        assert extra["step"] == 7
        # gc must rank by parsed step, never lexically over strays
        mgr._gc()
        assert mgr.latest_step() == 7


def test_latest_step_empty_and_strays_only():
    import os

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        assert mgr.latest_step() is None
        os.makedirs(os.path.join(d, "step_garbage"))
        assert mgr.latest_step() is None


def test_checkpoint_restack_adapter():
    """Elastic reshard: stage-stacked leaves restack across pipeline depths
    (the launch/train.py resume path)."""
    import numpy as onp

    L = 6  # true layer count; old mesh d_p=2 (L_s=3), new mesh d_p=4 (L_s=2, pad)
    saved = onp.arange(2 * 3 * 4, dtype=onp.float32).reshape(2, 3, 4)

    def restack(a, tmpl):
        flat = a.reshape(a.shape[0] * a.shape[1], *a.shape[2:])[:L]
        new_dp, new_ls = tmpl.shape[0], tmpl.shape[1]
        pad = new_dp * new_ls - L
        if pad:
            flat = onp.concatenate(
                [flat, onp.zeros((pad, *flat.shape[1:]), flat.dtype)])
        return flat.reshape(new_dp, new_ls, *flat.shape[1:])

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(0, {"w": jnp.asarray(saved)})
        tmpl = {"w": jnp.zeros((4, 2, 4))}       # d_p=4, L_s=2 (2 pad slots)
        restored, _ = mgr.restore(tmpl, adapt=restack)
        out = onp.asarray(restored["w"])
        assert out.shape == (4, 2, 4)
        onp.testing.assert_array_equal(out.reshape(8, 4)[:L],
                                       saved.reshape(6, 4))
        assert (out.reshape(8, 4)[L:] == 0).all()
