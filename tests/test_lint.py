"""Pipeline program auditor (repro.lint) tests.

Three layers:

* **Golden known-bad fixtures** — four deliberately broken programs/plans
  (forced f64 upcast, dropped donation, incomplete bucket key, broken
  ppermute ring), each tripping exactly its pass and none of the others.
* **Pinning regressions** — the auditor's findings on the real tree were
  fixed in this PR (bf16->f32 promotion in the streaming-CE fold /
  blocked-flash QK, non-donated error-feedback state in the AOT train
  step); these tests pin the fixes so they cannot silently regress.
* **Wiring** — the CompileCache lint hook (warn counts, error aborts
  before the cache insert), the CacheStore offline audit, and the
  ``python -m repro.lint`` CLI (clean registry sweep at ``--lint error``).

Anything needing more than the single real CPU device runs in a
subprocess with its own XLA_FLAGS (same convention as
test_runtime_pipeline.py).
"""

import copy
import hashlib
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import ClusterSpec, CostModel, PlannerConfig, plan_batch
from repro.core.plan import ExecutionPlan
from repro.core.schedule import stream_perm
from repro.lint import (
    LintError,
    LintReport,
    ProgramArtifacts,
    available_passes,
    check_bucket_key_completeness,
    check_ppermute_perm,
    make_cache_lint,
    run_plan_checks,
    run_program_checks,
    stablehlo_donors,
)
from repro.lint.jaxpr_checks import iter_eqns

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _spec():
    from repro.core import ModelSpec
    return ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8,
                     n_kv_heads=4, head_dim=32, d_ff=1024, vocab=512)


def _plan(d_p=4, d_s=4, **cfg):
    cm = CostModel(_spec(), ClusterSpec(d_p=d_p, d_s=d_s))
    return plan_batch(cm, [512, 384, 256, 256],
                      PlannerConfig(bucket_rounding=64, **cfg))


def _only_pass(report: LintReport, pass_name: str):
    """Assert every finding in ``report`` belongs to ``pass_name``."""
    assert report.findings, f"expected {pass_name} to fire: {report.summary()}"
    others = [f for f in report.findings if f.pass_name != pass_name]
    assert not others, f"unexpected cross-pass findings: {others}"


# ---------------------------------------------------------------------------
# golden fixture 1: forced f64 upcast
# ---------------------------------------------------------------------------


def test_golden_f64_fixture():
    def bad(x):
        return (x.astype(jnp.float64) * 2.0).sum()

    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(bad)(jnp.ones((8,), jnp.bfloat16))
    report = run_program_checks(ProgramArtifacts(jaxpr=jx))
    _only_pass(report, "program-f64")
    assert all(f.severity == "error" for f in report.findings)


def test_f64_hlo_text_tier():
    """Without a jaxpr the pass falls back to counting f64 types in HLO."""
    art = ProgramArtifacts(hlo="ENTRY %main { %x = f64[8]{0} parameter(0) }")
    report = run_program_checks(art)
    _only_pass(report, "program-f64")


# ---------------------------------------------------------------------------
# golden fixture 2: bf16 -> f32 upcast around a matmul
# ---------------------------------------------------------------------------


def test_golden_upcast_fixture():
    def bad(a, b):
        return jnp.einsum("td,vd->tv", a.astype(jnp.float32),
                          b.astype(jnp.float32))

    jx = jax.make_jaxpr(bad)(jnp.ones((4, 8), jnp.bfloat16),
                             jnp.ones((6, 8), jnp.bfloat16))
    report = run_program_checks(ProgramArtifacts(jaxpr=jx))
    _only_pass(report, "program-f32-upcast")


def test_upcast_detected_across_scan_scope():
    """The streaming-CE shape: one operand converted OUTSIDE the scan
    whose body runs the dot (the convert enters the body as an invar)."""
    def bad(h, wb):
        hf = h.astype(jnp.float32)

        def body(carry, w):
            return carry + jnp.einsum("td,vd->tv", hf,
                                      w.astype(jnp.float32)).sum(), None

        out, _ = jax.lax.scan(body, jnp.float32(0), wb)
        return out

    jx = jax.make_jaxpr(bad)(jnp.ones((4, 8), jnp.bfloat16),
                             jnp.ones((3, 6, 8), jnp.bfloat16))
    report = run_program_checks(ProgramArtifacts(jaxpr=jx))
    _only_pass(report, "program-f32-upcast")


def test_upcast_ignores_native_f32_operands():
    """A softmax-over-f32-stats matmul is NOT the convert-everything
    pattern; it must not be flagged."""
    def fine(p, v):
        return jnp.einsum("ts,sd->td", p, v.astype(jnp.float32))

    jx = jax.make_jaxpr(fine)(jnp.ones((4, 6), jnp.float32),
                              jnp.ones((6, 8), jnp.bfloat16))
    report = run_program_checks(ProgramArtifacts(jaxpr=jx))
    assert not report.by_pass("program-f32-upcast"), report.summary()


# ---------------------------------------------------------------------------
# golden fixture 3: dropped donation
# ---------------------------------------------------------------------------


def test_golden_dropped_donation_fixture():
    """StableHLO carries a deferred donor marker (``jax.buffer_donor``,
    the shard_map/train-step form) but the compiled HLO realized no
    alias for it.

    Synthetic texts: jax strips *lowering-time-unusable* donations from
    the StableHLO it emits, so the dropped-at-XLA shape this pass hunts
    can't be produced by a toy jit — only by a real program whose output
    type drifted, which is exactly what must not exist in the tree."""
    stablehlo = (
        "module @jit_f {\n"
        "  func.func public @main("
        "%arg0: tensor<2048xf32> {jax.buffer_donor = true}, "
        "%arg1: tensor<2048xf32> {jax.buffer_donor = true}) -> "
        "(tensor<2048xf32>, tensor<2048xbf16>) {\n"
        "  }\n}\n")
    hlo = ("HloModule jit_f, is_scheduled=true, "
           "input_output_alias={ {0}: (0, {}, may-alias) }, "
           "entry_computation_layout={(f32[2048]{0}, f32[2048]{0})->"
           "(f32[2048]{0}, bf16[2048]{0})}\n\nENTRY %main {}\n")
    report = run_program_checks(ProgramArtifacts(stablehlo=stablehlo,
                                                 hlo=hlo))
    dropped = report.by_pass("program-donation")
    assert len(dropped) == 1, report.summary()
    assert "silently dropped" in dropped[0].message
    assert "args [1]" in dropped[0].message
    others = [f for f in report.findings
              if f.pass_name != "program-donation"]
    assert not others, others


def test_donation_clean_when_aliased():
    def f(x):
        return x + 1

    lowered = jax.jit(f, donate_argnums=(0,)).lower(
        jnp.ones((2048,), jnp.float32))
    compiled = lowered.compile()
    art = ProgramArtifacts(stablehlo=lowered.as_text(),
                           hlo=compiled.as_text())
    report = run_program_checks(art)
    assert not report.by_pass("program-donation"), report.summary()


def test_donation_suspect_non_donated_state():
    """A large non-donated input whose exact type matches an un-aliased
    output is a donation suspect (the satellite-1 err-state shape)."""
    def f(x, state):
        return x + 1, state * 2

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        lowered = jax.jit(f, donate_argnums=(0,)).lower(
            jnp.ones((2048,), jnp.float32), jnp.ones((4096,), jnp.float32))
        compiled = lowered.compile()
    report = run_program_checks(ProgramArtifacts(
        stablehlo=lowered.as_text(), hlo=compiled.as_text()))
    sus = report.by_pass("program-donation")
    assert any("non-donated" in f.message for f in sus), report.summary()


# ---------------------------------------------------------------------------
# golden fixture 4: broken ppermute ring
# ---------------------------------------------------------------------------


def test_golden_broken_ppermute_ring():
    # colliding destination: two streams write device 1
    probs = check_ppermute_perm([(0, 1), (1, 1)], 2)
    assert any("destination" in p for p in probs)
    # out-of-range pair
    probs = check_ppermute_perm([(0, 2)], 2)
    assert any("out of range" in p for p in probs)
    # a chain is not a closed ring when the schedule demands one
    probs = check_ppermute_perm(stream_perm(4), 4, require_full=True)
    assert any("total permutation" in p for p in probs)
    # the real perms are valid
    assert check_ppermute_perm(stream_perm(4), 4) == []
    assert check_ppermute_perm(stream_perm(4, ring=True), 4,
                               require_full=True) == []


def test_stream_perm_is_the_executor_perm():
    """One definition of the hand-off permutation: the lint pass audits
    the same function the executor runs."""
    import inspect

    from repro.runtime import executor

    assert stream_perm(1) == [] and stream_perm(1, ring=True) == []
    assert stream_perm(4) == [(0, 1), (1, 2), (2, 3)]
    assert stream_perm(4, ring=True) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert "stream_perm" in inspect.getsource(executor.ppermute_streams)


# ---------------------------------------------------------------------------
# bucket-key completeness: clean on the real key, fails per erased axis
# ---------------------------------------------------------------------------


def test_bucket_key_completeness_clean():
    assert check_bucket_key_completeness(_plan(), 4) == []


@pytest.mark.parametrize("axis,const", [
    ("schedule", "gpipe-1f1b"),
    ("v_stages", 1),
    ("ckpt", "u0"),
    ("split_bwd", False),
    ("dtype", "bfloat16"),
])
def test_bucket_key_incompleteness_detected(monkeypatch, axis, const):
    """Erase one axis from bucket_key() (freeze its field to a constant)
    and the completeness check must flag exactly that axis."""
    orig = ExecutionPlan.bucket_key

    def erased(self, d_s, **kw):
        return orig(self, d_s, **kw)._replace(**{axis: const})

    monkeypatch.setattr(ExecutionPlan, "bucket_key", erased)
    probs = check_bucket_key_completeness(_plan(), 4)
    assert any(a == axis for a, _ in probs), probs


def test_plan_checks_clean_on_real_plans():
    for schedule, v in [(None, 0), ("gpipe-1f1b", 0),
                        ("interleaved-1f1b", 2), ("zero-bubble-h1", 0)]:
        plan = _plan(schedule=schedule, v_stages=v)
        report = run_plan_checks(plan, 4, 4)
        assert report.ok, f"{schedule} v={v}: {report.summary()}"
        assert set(report.passes_run) == {
            p.name for p in available_passes("plan")}


def test_registry_plan_sweep_clean():
    """Every registry arch's planner output passes the plan audit at a
    tiny geometry (the jax-free half of the CI lint-programs job)."""
    from repro.configs import arch_names, get_arch

    for name in arch_names():
        cfg = get_arch(name).reduced()
        cm = CostModel(cfg.spec, ClusterSpec(d_p=2, d_s=2))
        plan = plan_batch(cm, [256, 256, 128, 384],
                          PlannerConfig(bucket_rounding=64))
        report = run_plan_checks(plan, 2, 2)
        assert report.ok, f"{name}: {report.summary()}"


# ---------------------------------------------------------------------------
# pinning regressions for the satellite fixes
# ---------------------------------------------------------------------------


def _kernel_report(fn, *args):
    jx = jax.make_jaxpr(fn)(*args)
    return run_program_checks(ProgramArtifacts(jaxpr=jx)), jx


def test_pin_streaming_ce_stats_no_upcast():
    from repro.kernels.ref import streaming_ce_stats

    h = jnp.ones((32, 16), jnp.bfloat16)
    w = jnp.ones((64, 16), jnp.bfloat16)
    t = jnp.zeros((32,), jnp.int32)
    report, jx = _kernel_report(
        lambda h, w, t: streaming_ce_stats(h, w, t, block_v=32), h, w, t)
    assert not report.by_pass("program-f32-upcast"), report.summary()
    # the fold still accumulates in f32: bf16 operands, f32 dot output
    dots = [e for e in iter_eqns(jx) if e.primitive.name == "dot_general"]
    assert dots and all(str(e.outvars[0].aval.dtype) == "float32"
                        for e in dots)
    assert all(str(iv.aval.dtype) == "bfloat16"
               for e in dots for iv in e.invars[:2])


def test_pin_streaming_ce_matches_reference():
    """preferred_element_type fix is numerics-preserving: bf16 products
    are exact in f32, so the streamed loss still matches the full-logits
    oracle."""
    from repro.kernels.ref import (cross_entropy_reference,
                                   streaming_cross_entropy)

    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(24, 16)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(50, 16)), jnp.bfloat16)
    t = jnp.asarray(rng.integers(0, 50, 24), jnp.int32)
    valid = jnp.asarray(rng.random(24) > 0.2)
    loss_s, n_s = streaming_cross_entropy(h, w, t, valid, block_v=16)
    loss_r, n_r = cross_entropy_reference(h, w, t, valid)
    np.testing.assert_allclose(float(loss_s), float(loss_r),
                               rtol=2e-5, atol=2e-5)
    assert float(n_s) == float(n_r)


def test_pin_blocked_flash_no_upcast_and_parity():
    from repro.kernels.ref import (blocked_flash_attention,
                                   flash_attention_reference)

    rng = np.random.default_rng(1)
    T, S, H, D = 16, 24, 2, 8
    q = jnp.asarray(rng.normal(size=(T, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(S, H, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(S, H, D)), jnp.bfloat16)
    seg_q = jnp.zeros((T,), jnp.int32)
    seg_kv = jnp.zeros((S,), jnp.int32)
    pos_q = jnp.arange(T, dtype=jnp.int32) + (S - T)
    pos_kv = jnp.arange(S, dtype=jnp.int32)

    report, _ = _kernel_report(
        lambda *a: blocked_flash_attention(*a, block_kv=8),
        q, k, v, seg_q, seg_kv, pos_q, pos_kv)
    assert not report.by_pass("program-f32-upcast"), report.summary()
    out = blocked_flash_attention(q, k, v, seg_q, seg_kv, pos_q, pos_kv,
                                  block_kv=8)
    ref = flash_attention_reference(q, k, v, seg_q, seg_kv, pos_q, pos_kv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_pin_err_state_donated():
    """The compress_pod_grads error-feedback state (arg 2) is donated:
    the program-donation finding this PR fixed must not come back."""
    from repro.optim import init_error_state, init_opt_state
    from repro.runtime import TrainStepBuilder, batch_struct, make_geometry

    from repro.configs import get_arch

    cfg = get_arch("gemma3-1b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    geom = make_geometry(cfg, mesh, n_chunks=2, cap=16, ctx_cap=16,
                         l_ckpt=0, compute_dtype=jnp.bfloat16)
    builder = TrainStepBuilder(cfg, mesh, geom, compress_pod_grads=True)
    params_shape = builder.abstract_params()
    opt_shape = jax.eval_shape(init_opt_state, params_shape)
    err_shape = jax.eval_shape(init_error_state, params_shape)
    bstruct = batch_struct(geom, 1)
    lowered = builder.build(params_shape).lower(params_shape, opt_shape,
                                                err_shape, bstruct)
    donors = stablehlo_donors(lowered.as_text())
    n_state = (len(jax.tree.leaves(params_shape))
               + len(jax.tree.leaves(opt_shape))
               + len(jax.tree.leaves(err_shape)))
    assert set(range(n_state)) <= donors, \
        f"state args 0..{n_state - 1} must all be donated, got {donors}"
    # the default (err=None) path still builds with donate_argnums=(0,1,2)
    b2 = TrainStepBuilder(cfg, mesh, geom)
    p2 = b2.abstract_params()
    b2.build(p2).lower(p2, jax.eval_shape(init_opt_state, p2), None,
                       batch_struct(geom, 1))


# ---------------------------------------------------------------------------
# CompileCache hook + CacheStore audit wiring
# ---------------------------------------------------------------------------


class _FakeCompiled:
    def __init__(self, text):
        self._text = text

    def as_text(self):
        return self._text


def test_compile_cache_lint_warn_counts():
    from repro.runtime.compile_cache import CompileCache

    logs = []
    cache = CompileCache(name="t", lint=make_cache_lint("warn",
                                                        log=logs.append))
    value = cache.get("k", lambda: _FakeCompiled(
        "ENTRY %main { %x = f64[8]{0} parameter(0) }"))
    assert isinstance(value, _FakeCompiled)
    assert cache.stats.lint_findings == 1
    assert cache.stats.lint_errors == 1
    assert any("[lint]" in line for line in logs)
    # warm hits are not re-audited
    cache.get("k", lambda: pytest.fail("should be cached"))
    assert cache.stats.lint_findings == 1


def test_compile_cache_lint_error_blocks_insert():
    from repro.runtime.compile_cache import CompileCache

    cache = CompileCache(name="t", lint=make_cache_lint("error"))
    with pytest.raises(LintError):
        cache.get("k", lambda: _FakeCompiled(
            "ENTRY %main { %x = f64[8]{0} parameter(0) }"))
    # the hazardous executable never entered the cache: a clean rebuild
    # under the same key compiles fresh and is accepted
    clean = cache.get("k", lambda: _FakeCompiled(
        "ENTRY %main { %x = f32[8]{0} parameter(0) }"))
    assert clean.as_text().startswith("ENTRY")
    assert cache.stats.misses == 2


def test_cache_store_audit(tmp_path):
    from repro.runtime.cache_store import CacheStore

    store = CacheStore(tmp_path, fingerprint={"v": "fp-test"})

    def write_entry(stem, blob, *, sha=None, orphan=False):
        meta = {"fingerprint": "fp-test", "key": stem,
                "payload_sha": sha or hashlib.sha256(blob).hexdigest(),
                "payload_bytes": len(blob), "created": 0.0}
        (tmp_path / f"{stem}.meta.json").write_text(json.dumps(meta))
        if not orphan:
            (tmp_path / f"{stem}.bin").write_bytes(blob)

    write_entry("good__fp", b"payload-bytes")
    write_entry("corrupt__fp", b"payload-bytes", sha="0" * 64)
    write_entry("orphan__fp", b"gone", orphan=True)

    rows = {r["entry"]: r for r in store.audit()}
    assert rows["good__fp.meta.json"]["problems"] == []
    assert any("sha256 mismatch" in p
               for p in rows["corrupt__fp.meta.json"]["problems"])
    assert any("orphan" in p
               for p in rows["orphan__fp.meta.json"]["problems"])


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _run_cli(args, timeout=900):
    env = dict(os.environ, PYTHONPATH=_SRC)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m", "repro.lint", *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_cli_cache_dir_audit(tmp_path):
    blob = b"ok-bytes"
    meta = {"fingerprint": "x", "key": "k",
            "payload_sha": hashlib.sha256(blob).hexdigest(),
            "payload_bytes": len(blob), "created": 0.0}
    (tmp_path / "e__f.meta.json").write_text(json.dumps(meta))
    (tmp_path / "e__f.bin").write_bytes(blob)
    r = _run_cli(["--cache-dir", str(tmp_path), "--lint", "error"])
    assert r.returncode == 0, r.stdout + r.stderr

    (tmp_path / "e__f.bin").write_bytes(b"flipped")
    r = _run_cli(["--cache-dir", str(tmp_path), "--lint", "error"])
    assert r.returncode == 1, r.stdout + r.stderr
    assert "sha256 mismatch" in r.stdout


def test_cli_plan_sweep_error_mode():
    """Plan-tier audit of the full registry is finding-free (the fast
    half of the CI zero-findings baseline)."""
    r = _run_cli(["--all", "--plan-only", "--lint", "error"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[lint] clean" in r.stdout


def test_cli_program_audit_error_mode(tmp_path):
    """Full program audit (train + serve) of one representative arch is
    finding-free at --lint error, and emits the JSON report artifact."""
    out = tmp_path / "lint.json"
    r = _run_cli(["--arch", "gemma3-1b", "--target", "train,serve",
                  "--lint", "error", "--json", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rep = json.loads(out.read_text())
    assert rep["total_findings"] == 0
    progs = rep["subjects"][0]["programs"]
    assert progs["train"]["n_findings"] == 0
    assert progs["serve"]["n_findings"] == 0
    # both tiers really ran their passes
    assert "program-f32-upcast" in progs["train"]["passes_run"]
    assert "program-donation" in progs["train"]["passes_run"]
