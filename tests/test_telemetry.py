"""Telemetry subsystem: timeline collection, atomic stats I/O, calibration
round-trip, drift detectors, and the deterministic straggler injector."""

import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core import CostModel, PlannerConfig, plan_batch
from repro.core.planner import estimate_plan_time
from repro.core.schedule import WGRAD_FRACTION
from repro.ft import StragglerInjector
from repro.telemetry import (Cusum, MixTracker, StepSample, StepTimeline,
                             atomic_write_json, fit_calibration,
                             plan_components, read_json, read_jsonl)
from repro.telemetry.calibrate import BWD_MULT, fit_stage_slowdowns


# ---------------------------------------------------------------------------
# StepTimeline
# ---------------------------------------------------------------------------

def test_timeline_ring_and_counters():
    tl = StepTimeline(capacity=4)
    for i in range(10):
        tl.record("step", i, wall_s=0.1)
    snap = tl.snapshot()
    assert snap["by_kind"]["step"] == 10          # counters never truncate
    assert snap["events"] == 10
    assert [e["step"] for e in tl.events()] == [6, 7, 8, 9]  # ring = tail


def test_timeline_bucket_ema_and_probe(tmp_path):
    tl = StepTimeline(capacity=16, spill_dir=str(tmp_path))
    tl.record_step(0, "bkA", 1.0, tokens=10, loss=2.0, per_stage_s=None,
                   probed=False)
    tl.record_step(1, "bkA", 2.0, tokens=10, loss=2.0,
                   per_stage_s=[0.5, 1.5], probed=True)
    snap = tl.snapshot()
    b = snap["per_bucket"]["bkA"]
    assert b["n"] == 2 and b["last_s"] == 2.0
    assert 1.0 < b["ema_s"] < 2.0                 # EMA between the samples
    assert snap["by_kind"]["probe"] == 1
    tl.close()
    lines = list(read_jsonl(tmp_path / "timeline-train.jsonl"))
    kinds = [ln["kind"] for ln in lines]
    assert "step" in kinds and "probe" in kinds


def test_timeline_spill_failure_never_raises(tmp_path):
    tl = StepTimeline(capacity=4, spill_dir=str(tmp_path))
    tl._spill.close()                             # sabotage the spill file
    tl.record("step", 0, wall_s=0.1)              # must not raise
    assert tl.snapshot()["dropped_spill_writes"] == 1
    tl.close()


# ---------------------------------------------------------------------------
# Atomic stats writes
# ---------------------------------------------------------------------------

def test_atomic_write_and_read(tmp_path):
    p = tmp_path / "stats.json"
    atomic_write_json(p, {"a": 1})
    atomic_write_json(p, {"a": 2})
    assert read_json(p) == {"a": 2}
    assert [f for f in os.listdir(tmp_path) if f.startswith(".tmp-")] == []


def test_read_jsonl_skips_torn_tail(tmp_path):
    p = tmp_path / "log.jsonl"
    p.write_text('{"step": 0}\n{"step": 1}\n{"step": 2, "x": ')
    assert [r["step"] for r in read_jsonl(p)] == [0, 1]


def test_atomic_write_survives_writer_kill(tmp_path):
    """Regression: kill the writer mid-dump — the reader must only ever see
    the previous complete file, never a torn one."""
    target = tmp_path / "stats.json"
    atomic_write_json(target, {"generation": 0, "payload": "x" * 64})
    prog = textwrap.dedent(f"""
        import sys
        sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__),
                                              "..", "src"))})
        from repro.telemetry import atomic_write_json
        # a large payload keeps the dump window open long enough to be
        # killable; loop so the parent can kill at an arbitrary moment
        payload = "y" * (1 << 20)
        i = 1
        print("ready", flush=True)
        while True:
            atomic_write_json({repr(str(target))},
                              {{"generation": i, "payload": payload}})
            i += 1
    """)
    proc = subprocess.Popen([sys.executable, "-c", prog],
                            stdout=subprocess.PIPE)
    try:
        proc.stdout.readline()                    # writer is live
        time.sleep(0.2)                           # let some dumps land
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=10)
    finally:
        if proc.poll() is None:
            proc.kill()
    data = read_json(target)
    assert data is not None, "reader saw a torn stats file"
    assert data["payload"][0] in ("x", "y")
    assert len(data["payload"]) in (64, 1 << 20)  # a COMPLETE generation


# ---------------------------------------------------------------------------
# Calibration: round-trip + robustness
# ---------------------------------------------------------------------------

def _sample_plans(cm, n, seed=0, batch=8):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        lengths = [int(x) for x in np.clip(rng.lognormal(8, 1, size=batch),
                                           256, 32768)]
        out.append((lengths, plan_batch(cm, lengths, PlannerConfig())))
    return out


def test_calibration_round_trip_within_5pct(cost_model):
    """Synthesize step times from KNOWN component scales; the fit must
    recover the per-token forward/backward/wgrad times within 5%."""
    true = {"quad": 1.5, "lin": 0.8, "over": 1.0, "rec": 1.0, "comm": 1.3}
    rng = np.random.default_rng(1)
    samples = []
    for i, (lengths, plan) in enumerate(_sample_plans(cost_model, 16)):
        comp = plan_components(cost_model, plan)
        t = sum(true[k] * v for k, v in comp.items())
        samples.append(StepSample(
            step=i, measured_s=t * (1 + 0.01 * rng.standard_normal()),
            components=comp,
            sp_policy=plan.sp.policy if plan.sp is not None else "none"))
    cal = fit_calibration(samples, d_p=cost_model.cluster.d_p)
    cl = cost_model.cluster
    tf_true = cost_model.coeffs.alpha2 * true["lin"] / cl.n_devices
    assert abs(cal.t_f_per_token(cost_model) - tf_true) / tf_true < 0.05
    assert (abs(cal.t_b_per_token(cost_model) - BWD_MULT * tf_true)
            / (BWD_MULT * tf_true) < 0.05)
    tw_true = WGRAD_FRACTION * BWD_MULT * tf_true
    assert abs(cal.t_w_per_token(cost_model) - tw_true) / tw_true < 0.05
    assert abs(cal.scales["quad"] - true["quad"]) / true["quad"] < 0.05
    assert cal.residual_rel_rms < 0.05


def test_calibration_absorbs_unit_conversion(cost_model):
    """Measured wall SECONDS vs model units: the fit must still converge
    (scale-free active-column test + wide clip), with small residuals."""
    rng = np.random.default_rng(2)
    samples = []
    for i, (lengths, plan) in enumerate(_sample_plans(cost_model, 12)):
        comp = plan_components(cost_model, plan)
        t = 7.3 * sum(comp.values())              # pure unit change
        samples.append(StepSample(
            step=i, measured_s=t * (1 + 0.01 * rng.standard_normal()),
            components=comp,
            sp_policy=plan.sp.policy if plan.sp is not None else "none"))
    cal = fit_calibration(samples, d_p=cost_model.cluster.d_p)
    assert cal.residual_rel_rms < 0.05
    assert cal.scales["lin"] > 2.0                # absorbed the 7.3x


def test_calibration_robust_to_outliers(cost_model):
    true = {"quad": 1.2, "lin": 1.0, "over": 1.0, "rec": 1.0, "comm": 1.0}
    rng = np.random.default_rng(3)
    samples = []
    for i, (lengths, plan) in enumerate(_sample_plans(cost_model, 16)):
        comp = plan_components(cost_model, plan)
        t = sum(true[k] * v for k, v in comp.items())
        if i in (4, 11):                          # GC pause / noisy host
            t *= 5.0
        samples.append(StepSample(
            step=i, measured_s=t * (1 + 0.01 * rng.standard_normal()),
            components=comp,
            sp_policy=plan.sp.policy if plan.sp is not None else "none"))
    cal = fit_calibration(samples, d_p=cost_model.cluster.d_p)
    assert abs(cal.scales["quad"] - true["quad"]) / true["quad"] < 0.10


def test_calibration_apply_and_dict_round_trip(cost_model):
    samples = []
    for i, (lengths, plan) in enumerate(_sample_plans(cost_model, 8)):
        comp = plan_components(cost_model, plan)
        samples.append(StepSample(step=i, measured_s=1.4 * sum(comp.values()),
                                  components=comp, sp_policy="none"))
    cal = fit_calibration(samples, d_p=cost_model.cluster.d_p,
                          fingerprint="4x4:tiny", version=3)
    from repro.telemetry import CostCalibration
    back = CostCalibration.from_dict(cal.to_dict())
    assert back.version == 3 and back.fingerprint == "4x4:tiny"
    assert back.scales == pytest.approx(cal.scales)
    cm2 = back.apply(cost_model)
    assert cm2.coeffs.alpha1 == pytest.approx(
        cost_model.coeffs.alpha1 * cal.scales["quad"])


def test_calibration_drops_stale_mesh_slowdowns(cost_model):
    from repro.telemetry import CostCalibration
    cal = CostCalibration(version=1, scales={k: 1.0 for k in
                                             ("quad", "lin", "over", "rec",
                                              "comm")},
                          comm_scales={}, stage_slowdowns=[1.0, 2.0],
                          fingerprint="2x2:tiny")
    cm2 = cal.apply(cost_model)                   # d_p=4 != len 2
    assert cm2.stage_slowdowns is None


def test_fit_stage_slowdowns():
    probes = [[1.0, 1.0, 1.8, 1.0], [1.1, 0.9, 1.9, 1.0]]
    slow = fit_stage_slowdowns(probes, d_p=4)
    assert slow is not None
    assert slow[2] > 1.5
    assert slow[0] == slow[1] == slow[3] == 1.0   # snapped to baseline
    assert fit_stage_slowdowns([[1.0, 1.0]], d_p=2) is None


# ---------------------------------------------------------------------------
# Drift detectors
# ---------------------------------------------------------------------------

def test_cusum_detects_sustained_shift():
    c = Cusum(k=0.05, h=0.5)
    rng = np.random.default_rng(0)
    for _ in range(50):
        assert not c.update(float(0.02 * rng.standard_normal()))
    fired = any(c.update(0.3 + float(0.02 * rng.standard_normal()))
                for _ in range(10))
    assert fired
    c.reset()
    assert not c.update(0.0)


def test_mix_tracker_detects_phase_change():
    m = MixTracker(rel=0.3)
    rng = np.random.default_rng(0)
    for _ in range(10):
        assert not m.update([int(x) for x in rng.integers(100, 140, 8)])
    fired = any(m.update([int(x) for x in rng.integers(400, 520, 8)])
                for _ in range(6))
    assert fired
    m.settle()
    assert not m.update([int(x) for x in rng.integers(400, 520, 8)])


# ---------------------------------------------------------------------------
# Straggler injector
# ---------------------------------------------------------------------------

def test_injector_parse_and_determinism():
    inj = StragglerInjector.parse("2:2.5@3", 4, jitter=0.05, seed=7)
    assert inj.factors == {2: 2.5} and inj.start_step == 3
    assert not inj.active(2) and inj.active(3)
    a = inj.per_stage([1.0, 1.0, 1.0, 1.0], 5)
    b = inj.per_stage([1.0, 1.0, 1.0, 1.0], 5)
    assert a == b                                 # (seed, step) determinism
    assert a[1] > 2.0                             # stage 2 (1-based) slowed
    assert inj.wall(1.0, 5) > 2.0                 # worst factor gates wall
    assert inj.wall(1.0, 0) == pytest.approx(
        float(1.0 + 0.05 * np.random.default_rng((7, 0)).standard_normal(1)[0]))


def test_injector_rejects_bad_stage():
    with pytest.raises(ValueError):
        StragglerInjector.parse("5:2.0", 4)
