"""Schedule-backend subsystem tests (fast, host-side — the CI
schedule-parity job runs exactly this file).

Covers the acceptance contracts of the schedule registry:

* the pure-python occupancy simulator's measured bubble fraction equals the
  executor's tick-count formula (``scan_bubble_fraction``) for every
  backend over a (n, d_p, v) grid — and the executor's traced arithmetic
  (``runtime.executor.schedule_tick_coords``) agrees with the spec mapping
  tick for tick;
* ``StageProgram.n_ticks`` delegates to the same formula;
* the bubble model orders backends sensibly (ZB-H1 < 1F1B; interleaved
  shrinks with v) and the planner's pick lands on ``ExecutionPlan`` and in
  ``bucket_key()`` — schedules never share a compile-cache bucket.
"""

import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (ClusterSpec, CostModel, ExecutionPlan, ModelSpec,
                        PlannerConfig, available_schedules, choose_schedule,
                        get_schedule, plan_batch, register_schedule,
                        simulate_occupancy, simulate_schedule)
from repro.core.schedule import ScheduleSpec

# small deterministic smoke grid — the hypothesis sweeps below are the
# real coverage (random (n, d_p, v) far beyond these hand-picked points),
# but property cases skip on a bare interpreter (conftest shim), so a
# couple of fixed points keep the invariants exercised everywhere
GRID = [(4, 2), (7, 4), (16, 8)]


@st.composite
def _spec_and_grid(draw):
    """Random (spec, n_items, d_p): any registered backend, interleaved at
    any v in [1, 4] (not just divisors of a layer block — the tick mapping
    must hold for every v), n and d_p over ranges that cover n < d_p,
    n == d_p, ragged groups (d_p not dividing n) and single-device."""
    name = draw(st.sampled_from(
        ["gpipe-1f1b", "zero-bubble-h1", "interleaved-1f1b"]))
    v = draw(st.integers(1, 4)) if name == "interleaved-1f1b" else 1
    n = draw(st.integers(1, 40))
    d_p = draw(st.integers(1, 8))
    return get_schedule(name, v), n, d_p


def _specs():
    out = [get_schedule("gpipe-1f1b"), get_schedule("zero-bubble-h1")]
    out += [get_schedule("interleaved-1f1b", v) for v in (1, 2, 3, 4)]
    return out


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

def test_registry_names():
    assert set(available_schedules()) >= {
        "gpipe-1f1b", "interleaved-1f1b", "zero-bubble-h1"}
    with pytest.raises(ValueError):
        get_schedule("totally-unknown")
    # non-interleaved backends reject virtual stages
    with pytest.raises(ValueError):
        get_schedule("gpipe-1f1b", 2)
    with pytest.raises(ValueError):
        get_schedule("zero-bubble-h1", 3)
    assert get_schedule("interleaved-1f1b", 4).v == 4


def test_register_custom_backend():
    register_schedule("test-custom", lambda v: ScheduleSpec("test-custom"))
    assert "test-custom" in available_schedules()
    assert get_schedule("test-custom").name == "test-custom"


# ---------------------------------------------------------------------------
# Property-based sweeps: the executor's traced arithmetic mirrors the spec
# mapping and the occupancy simulator satisfies its invariants for RANDOM
# (n, d_p, v, n_groups) — not just hand-picked grid points.
# ---------------------------------------------------------------------------

@settings(max_examples=80, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(_spec_and_grid())
def test_prop_executor_coords_match_spec(case):
    """engine-side ``schedule_tick_coords`` (overloaded arithmetic) ==
    ``ScheduleSpec.tick_coords`` for every (t, p) of the whole scan."""
    executor = pytest.importorskip("repro.runtime.executor")
    spec, n, d_p = case
    n_groups = spec.n_groups(n, d_p)
    for t in range(spec.scan_ticks(n, d_p)):
        for p in range(d_p):
            idx, v_idx, valid = executor.schedule_tick_coords(
                t, p, n=n, d_p=d_p, v=spec.v, n_groups=n_groups)
            m_ref, j_ref, valid_ref = spec.tick_coords(t, p, n, d_p)
            assert bool(valid) == bool(valid_ref), \
                (spec.name, spec.v, n, d_p, t, p)
            if valid_ref:
                assert (idx, v_idx) == (m_ref, j_ref), \
                    (spec.name, spec.v, n, d_p, t, p)


@settings(max_examples=80, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(_spec_and_grid())
def test_prop_occupancy_invariants(case):
    """simulate_occupancy (which raises on duplicate/missing work or
    causality violations) must additionally satisfy: every device runs
    exactly n*v useful slots, the grid spans exactly scan_ticks rows, and
    the measured bubble fraction equals the closed-form
    ``scan_bubble_fraction``."""
    spec, n, d_p = case
    occ = simulate_occupancy(spec, n, d_p)
    assert len(occ.grid) == spec.scan_ticks(n, d_p)
    per_device = [sum(1 for row in occ.grid if row[p] is not None)
                  for p in range(d_p)]
    assert per_device == [n * spec.v] * d_p, (spec.name, spec.v, n, d_p)
    assert occ.useful_slots == n * spec.v * d_p
    assert occ.bubble_fraction == pytest.approx(
        spec.scan_bubble_fraction(n, d_p), abs=1e-12)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(_spec_and_grid(),
       st.floats(0.1, 4.0), st.floats(0.1, 4.0))
def test_prop_event_sim_invariants(case, t_f, t_b):
    """Duration-independent invariants of the event simulator (the
    closed-form ``bubble_time`` is a MODEL, not a bound, away from the
    canonical t_b = 2 t_f point — so the properties pin what always
    holds): per-stage work is a makespan lower bound, the full 1F1B
    ramp an upper bound, the bubble fraction is a fraction, and ZB-H1's
    work-conserving W-grad filling never loses to plain 1F1B at equal
    durations."""
    spec, n, d_p = case
    sim = simulate_schedule(spec, n, d_p, t_f, t_b)
    assert sim["makespan"] >= n * (t_f + t_b) - 1e-9
    assert sim["makespan"] <= (n + d_p - 1) * (t_f + t_b) + 1e-9
    assert 0.0 <= sim["bubble_fraction"] <= 1.0
    zb = simulate_schedule(get_schedule("zero-bubble-h1"), n, d_p, t_f, t_b)
    g = simulate_schedule(get_schedule("gpipe-1f1b"), n, d_p, t_f, t_b)
    assert zb["makespan"] <= g["makespan"] + 1e-9


# ---------------------------------------------------------------------------
# Occupancy simulator == tick-count formula (deterministic smoke — the
# hypothesis sweeps above are the broad-coverage versions).
# ---------------------------------------------------------------------------

def test_occupancy_matches_scan_bubble_formula():
    for spec in _specs():
        for n, d_p in GRID:
            occ = simulate_occupancy(spec, n, d_p)
            assert len(occ.grid) == spec.scan_ticks(n, d_p)
            assert occ.bubble_fraction == pytest.approx(
                spec.scan_bubble_fraction(n, d_p), abs=1e-12), \
                (spec.name, spec.v, n, d_p)


def test_occupancy_coverage_and_causality():
    """simulate_occupancy raises on duplicate / missing (item, v_idx)
    work; beyond that, virtual stages of one item must run in ring order
    (item m cannot reach global virtual stage s before tick s)."""
    for spec in _specs():
        for n, d_p in GRID:
            occ = simulate_occupancy(spec, n, d_p)
            first_seen = {}
            for t, row in enumerate(occ.grid):
                for p, cell in enumerate(row):
                    if cell is None:
                        continue
                    m, j = cell
                    s = j * d_p + p  # global virtual stage
                    key = (m, s)
                    assert key not in first_seen
                    first_seen[key] = t
            for (m, s), t in first_seen.items():
                if s > 0 and (m, s - 1) in first_seen:
                    assert first_seen[(m, s - 1)] < t, (spec.name, m, s)


def test_executor_arithmetic_mirrors_spec():
    """The engine's traced mapping (pure overloaded arithmetic) equals the
    spec's pure-python mapping for every (t, p) of every grid point."""
    executor = pytest.importorskip("repro.runtime.executor")
    for spec in _specs():
        for n, d_p in GRID:
            n_groups = spec.n_groups(n, d_p)
            for t in range(spec.scan_ticks(n, d_p)):
                for p in range(d_p):
                    idx, v_idx, valid = executor.schedule_tick_coords(
                        t, p, n=n, d_p=d_p, v=spec.v, n_groups=n_groups)
                    m_ref, j_ref, valid_ref = spec.tick_coords(t, p, n, d_p)
                    assert bool(valid) == bool(valid_ref), \
                        (spec.name, spec.v, n, d_p, t, p)
                    if valid_ref:
                        assert (idx, v_idx) == (m_ref, j_ref), \
                            (spec.name, spec.v, n, d_p, t, p)


def test_stage_program_n_ticks_delegates():
    program_mod = pytest.importorskip("repro.runtime.program")
    for name, v in [("gpipe-1f1b", 1), ("interleaved-1f1b", 2),
                    ("zero-bubble-h1", 1)]:
        prog = program_mod.StageProgram(
            n_items=7, d_p=4, data_axis="data", tick=lambda *a: a,
            schedule=name, v=v)
        assert prog.n_ticks == get_schedule(name, v).scan_ticks(7, 4)
    # the default is the classic n + d_p - 1
    prog = program_mod.StageProgram(n_items=7, d_p=4, data_axis="data",
                                    tick=lambda *a: a)
    assert prog.n_ticks == 10


# ---------------------------------------------------------------------------
# Bubble model ordering + event simulator.
# ---------------------------------------------------------------------------

def test_interleaving_shrinks_scan_bubble():
    n, d_p = 16, 4
    fracs = [get_schedule("interleaved-1f1b", v).scan_bubble_fraction(n, d_p)
             for v in (1, 2, 4)]
    assert fracs[0] > fracs[1] > fracs[2]
    # v=1 equals the plain 1F1B inflation
    assert fracs[0] == pytest.approx(
        get_schedule("gpipe-1f1b").scan_bubble_fraction(n, d_p))


def test_zero_bubble_beats_1f1b_in_model_and_sim():
    t_f, t_b = 1.0, 2.0
    for n, d_p in [(8, 4), (16, 4), (12, 3)]:
        g = get_schedule("gpipe-1f1b")
        z = get_schedule("zero-bubble-h1")
        # closed form: ZB-H1 leaves one third of the 1F1B ramp
        assert z.bubble_time(n, d_p, t_f, t_b) == pytest.approx(
            g.bubble_time(n, d_p, t_f, t_b) / 3.0)
        sim_g = simulate_schedule(g, n, d_p, t_f, t_b)
        sim_z = simulate_schedule(z, n, d_p, t_f, t_b)
        # W-grad work fills the cooldown: strictly less idle AND an earlier
        # finish, never exceeding the closed-form ramp (the greedy event
        # sim is work-conserving, so it can only beat the analytic bound)
        assert sim_z["makespan"] < sim_g["makespan"]
        assert sim_z["bubble_time"] < sim_g["bubble_time"]
        assert sim_g["bubble_time"] <= g.bubble_time(n, d_p, t_f, t_b) + 1e-9
        assert sim_z["bubble_time"] <= z.bubble_time(n, d_p, t_f, t_b) + 1e-9


def test_interleaving_shrinks_simulated_bubble():
    for n, d_p in [(8, 4), (16, 4)]:
        sims = [simulate_schedule(get_schedule("interleaved-1f1b", v),
                                  n, d_p)["bubble_time"] for v in (1, 2, 4)]
        assert sims[0] > sims[1] > sims[2]


def _cm(d_p=4):
    m = ModelSpec(name="t", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                  head_dim=32, d_ff=1024, vocab=512)
    return CostModel(m, ClusterSpec(d_p=d_p, d_s=4))


def test_choose_schedule_prefers_lower_bubble():
    from repro.core.plan import Chunk, ChunkKind, Slice
    cm = _cm()  # n_layers=8, d_p=4 -> layers_per_stage=2, divisors {2}
    chunks = [Chunk(kind=ChunkKind.BATCHED, context=0,
                    slices=(Slice(i, 0, 1024, True),)) for i in range(8)]
    # default objective is the REALIZED executor bubble. ZB-H1's split
    # backward now compiles, pricing its bubble at
    # (d_p-1)(t_f + t_b - t_w); at this geometry interleaving's
    # (d_p-1)(t_f+t_b)/2 + ring-trip comm still narrowly wins
    best = choose_schedule(cm, chunks)
    assert (best.name, best.v) == ("interleaved-1f1b", 2)
    # under the MODELED objective (free-form W placement,
    # (d_p-1)(t_f + t_b - 2 t_w)), ZB-H1 beats interleaving at v=2
    assert choose_schedule(cm, chunks, realized=False).name == \
        "zero-bubble-h1"
    only_interleaved = [get_schedule("interleaved-1f1b", v) for v in (1, 2)]
    best2 = choose_schedule(cm, chunks, candidates=only_interleaved)
    assert best2.v == 2
    # single stage: nothing to schedule around
    assert choose_schedule(_cm(d_p=1), chunks).name == "gpipe-1f1b"


def test_auto_pick_capability_aware_zero_bubble(monkeypatch):
    """plan_batch's default pick ranks by the realized executor bubble,
    which is backend-capability-aware: with the split backward compiled
    (SPLIT_BWD_REALIZED, the default) zero-bubble-h1 can win the default
    pick outright; with the capability monkeypatched off (an executor
    whose backward stays the fused autodiff transpose) ZB-H1 collapses to
    1F1B's bubble and must never be auto-picked — the pre-split behavior,
    kept as a regression."""
    import repro.core.schedule as sched_mod
    cm = _cm()
    # full-degree SP pin: the schedule ranking below is calibrated for
    # full-axis sharding; the free planner backs the tiny model off to
    # none@1, which changes the hand-off/compute balance the scenario
    # depends on (the SP axis itself is covered in test_sp_policy.py)
    sp = dict(sp_policy="ulysses", sp_degree=4)
    # 2048-token chunks: hand-off cost makes interleaving's extra ring
    # trips pricier than ZB-H1's realized (d_p-1)(t_f + t_b - t_w) ramp
    plan = plan_batch(cm, [2048] * 8,
                      PlannerConfig(bucket_rounding=64, **sp))
    assert (plan.schedule, plan.v_stages) == ("zero-bubble-h1", 1)
    # v_stages=1 pin keeps only v=1 backends; ZB-H1 beats gpipe on the
    # realized bubble now that the W-drain exists in the HLO
    plan1 = plan_batch(cm, [2048] * 8,
                       PlannerConfig(bucket_rounding=64, v_stages=1, **sp))
    assert plan1.schedule == "zero-bubble-h1" and plan1.v_stages == 1
    # explicit v_stages>1 without a schedule implies interleaving at that
    # exact v — never a silent fallback to a v=1 backend
    plan2 = plan_batch(cm, [2048] * 8,
                       PlannerConfig(bucket_rounding=64, v_stages=2, **sp))
    assert (plan2.schedule, plan2.v_stages) == ("interleaved-1f1b", 2)

    # capability off: realized ZB == 1F1B, never auto-picked
    monkeypatch.setattr(sched_mod, "SPLIT_BWD_REALIZED", False)
    plan = plan_batch(cm, [2048] * 8,
                      PlannerConfig(bucket_rounding=64, **sp))
    assert (plan.schedule, plan.v_stages) == ("interleaved-1f1b", 2)
    plan1 = plan_batch(cm, [2048] * 8,
                       PlannerConfig(bucket_rounding=64, v_stages=1, **sp))
    assert plan1.schedule == "gpipe-1f1b" and plan1.v_stages == 1


def test_ranking_flips_to_zero_bubble_when_t_w_positive():
    """Regression for the planner bugfix: rank_schedule(realized=True)
    used to price ZB-H1's fill at zero (realized == 1F1B), so ZB-H1 could
    only ever win by tiebreak — which it lost to gpipe. With the compiled
    split, any t_w > 0 must flip the v=1 ranking to ZB-H1."""
    from repro.core.schedule import rank_schedule
    g = get_schedule("gpipe-1f1b")
    z = get_schedule("zero-bubble-h1")
    n, d_p, t_f, t_b = 8, 4, 1.0, 2.0
    # t_w == 0: nothing to drain, realized bubbles tie, tiebreak -> gpipe
    assert rank_schedule(z, n, d_p, t_f, t_b, t_w=0.0) > \
        rank_schedule(g, n, d_p, t_f, t_b, t_w=0.0)
    # any positive weight-grad share: ZB-H1 wins the realized ranking
    for t_w in (0.1, 0.5, 1.0):
        assert rank_schedule(z, n, d_p, t_f, t_b, t_w=t_w) < \
            rank_schedule(g, n, d_p, t_f, t_b, t_w=t_w)
    # capability off: back to the tie (ZB realized == 1F1B) -> gpipe
    assert z.realized_bubble_time(n, d_p, t_f, t_b, t_w=1.0,
                                  split_realized=False) == \
        g.realized_bubble_time(n, d_p, t_f, t_b)
    # realized sits between the model's ideal and plain 1F1B, converging
    # to the model as t_w -> 0 (the long-context regime)
    t_w = 0.5
    assert z.bubble_time(n, d_p, t_f, t_b, t_w) < \
        z.realized_bubble_time(n, d_p, t_f, t_b, t_w) < \
        g.bubble_time(n, d_p, t_f, t_b)


def test_drain_and_total_ticks():
    """split_bwd backends append one W-drain tick per (item, virtual
    stage); fused backends drain nothing."""
    z = get_schedule("zero-bubble-h1")
    g = get_schedule("gpipe-1f1b")
    i2 = get_schedule("interleaved-1f1b", 2)
    for n, d_p in GRID:
        assert z.drain_ticks(n, d_p) == n
        assert z.total_ticks(n, d_p) == z.scan_ticks(n, d_p) + n
        assert g.drain_ticks(n, d_p) == 0
        assert g.total_ticks(n, d_p) == g.scan_ticks(n, d_p)
        assert i2.drain_ticks(n, d_p) == 0
    assert z.drain_ticks(0, 4) == 0


# ---------------------------------------------------------------------------
# Planner + bucket key integration.
# ---------------------------------------------------------------------------

def test_plan_carries_schedule_and_serializes():
    cm = _cm()
    plan = plan_batch(cm, [512, 384, 256, 256],
                      PlannerConfig(bucket_rounding=64))
    assert plan.schedule in available_schedules()
    assert all(p.sched_backend in available_schedules()
               for p in plan.pipelines)
    back = ExecutionPlan.loads(plan.dumps())
    assert (back.schedule, back.v_stages) == (plan.schedule, plan.v_stages)
    assert [p.sched_backend for p in back.pipelines] == \
           [p.sched_backend for p in plan.pipelines]


def test_bucket_key_distinguishes_schedules():
    """No cross-schedule cache hits: identical geometry under different
    backends must land in different compile-cache buckets."""
    from repro.runtime.compile_cache import CompileCache
    cm = _cm()
    lengths = [512, 384, 256, 256]
    keys = {}
    for name, v in [("gpipe-1f1b", 0), ("zero-bubble-h1", 0),
                    ("interleaved-1f1b", 2)]:
        plan = plan_batch(cm, lengths, PlannerConfig(
            bucket_rounding=64, schedule=name, v_stages=v))
        keys[(name, v)] = plan.bucket_key(4)
    assert len(set(keys.values())) == 3
    # geometry fields of the key are schedule-independent (split_bwd is
    # NOT: zero-bubble-h1 resolves "auto" to a split backward)
    assert len({(k.n_chunks, k.cap, k.ctx_cap, k.l_ckpt, k.ckpt, k.dtype)
                for k in keys.values()}) == 1
    assert keys[("zero-bubble-h1", 0)].split_bwd is True
    assert keys[("gpipe-1f1b", 0)].split_bwd is False
    cache = CompileCache(name="sched-buckets")
    builds = []
    for key in keys.values():
        cache.get(key, lambda k=key: builds.append(k) or k)
    assert cache.stats.hits == 0 and cache.stats.misses == 3
    assert len(builds) == 3


def test_restack_elastic_preserves_interleaved_layer_order():
    """Elastic checkpoint reshard across pipeline depths must un-permute
    the interleaved (v>1) placement before re-stacking — flat[:L] on the
    raw stacking would scramble layers (regression)."""
    sharding = pytest.importorskip("repro.runtime.sharding")
    import numpy as np
    n_layers, v = 8, 2
    layers = np.arange(n_layers, dtype=np.float32)[:, None] * np.ones(
        (1, 3), np.float32)  # layer i's leaf filled with value i
    old = np.asarray(sharding.stack_stages(layers, 2, n_layers, v=v))
    new = sharding.restack_elastic(old, 4, 2, n_layers, v=v)
    assert new.shape == (4, 2, 3)
    back = np.asarray(sharding.unstack_stages(
        __import__("jax").numpy.asarray(new), n_layers, v=v))
    np.testing.assert_array_equal(back, layers)
    # round-trip at v=1 unchanged (classic contiguous restack)
    old1 = np.asarray(sharding.stack_stages(layers, 2, n_layers))
    new1 = sharding.restack_elastic(old1, 4, 2, n_layers)
    np.testing.assert_array_equal(
        np.asarray(sharding.unstack_stages(
            __import__("jax").numpy.asarray(new1), n_layers)), layers)
    # refuses layouts it cannot adapt: v must divide both block sizes
    assert sharding.restack_elastic(old, 4, 3, n_layers, v=2) is None
    assert sharding.restack_elastic(old, 2, 2, n_layers, v=2) is None


def test_pinned_schedule_is_respected():
    cm = _cm()
    plan = plan_batch(cm, [2048] * 6, PlannerConfig(
        bucket_rounding=64, schedule="interleaved-1f1b", v_stages=2))
    assert plan.schedule == "interleaved-1f1b" and plan.v_stages == 2
    assert all(p.sched_backend == "interleaved-1f1b" and p.v_stages == 2
               for p in plan.pipelines)
    with pytest.raises(ValueError):
        plan_batch(cm, [2048] * 6, PlannerConfig(schedule="nope"))
