"""CompileCache regression tests: registry lifetime (no process-wide
executable leak), live-bucket vs recompile accounting, per-key stat pruning
on eviction, and clear() semantics."""

import gc

import pytest

from repro.runtime.compile_cache import (CompileCache, global_cache_stats,
                                         reset_global_caches)


def test_registry_releases_dead_caches():
    """The module-global registry must hold caches weakly: a cache (and the
    executables it pins) dies with its last strong reference instead of
    accumulating across repeated in-process train/serve runs."""
    reset_global_caches()

    class Artifact:  # stand-in for a compiled executable
        pass

    alive = []

    def one_run():
        cache = CompileCache(name="run-cache")
        art = Artifact()
        alive.append(__import__("weakref").ref(art))
        cache.get(("bucket", 1), lambda: art)
        assert global_cache_stats()["caches"]["run-cache"]["misses"] == 1
        # cache goes out of scope here — nothing else references it

    for _ in range(3):
        one_run()
    gc.collect()
    stats = global_cache_stats()
    assert "run-cache" not in stats["caches"]
    assert stats["misses"] == 0 and stats["buckets_live"] == 0
    # the artifacts themselves were freed with their cache
    assert all(ref() is None for ref in alive)


def test_eviction_prunes_per_key_stats():
    cache = CompileCache(name="prune", capacity=2)
    for key in (1, 2, 3, 4):
        cache.get(key, lambda k=key: k)
    assert len(cache) == 2
    # only the RESIDENT buckets keep a per-key compile-seconds entry
    assert set(cache.stats.compile_seconds_per_key) == {repr(3), repr(4)}
    assert cache.stats.evictions == 2
    assert cache.stats.buckets_live == 2


def test_live_buckets_vs_recompiles():
    """A bounded cache that evicts and recompiles a key must not report the
    recompile as a new live bucket (the old ``buckets_compiled = misses``
    defect)."""
    cache = CompileCache(name="churn", capacity=1)
    cache.get("a", lambda: "A")
    cache.get("b", lambda: "B")   # evicts a
    cache.get("a", lambda: "A")   # recompile of a, evicts b
    s = cache.stats
    assert s.misses == 3
    assert s.recompiles == 1
    assert s.buckets_live == 1          # NOT 3
    d = s.as_dict()
    assert d["buckets_live"] == 1 and d["recompiles"] == 1
    assert "buckets_live" in s.summary() or "buckets=1" in s.summary()


def test_clear_keeps_or_resets_stats():
    cache = CompileCache(name="clear")
    cache.get(1, lambda: "x")
    cache.get(1, lambda: "x")
    assert cache.stats.hits == 1
    cache.clear()
    assert len(cache) == 0
    assert cache.stats.buckets_live == 0
    assert cache.stats.compile_seconds_per_key == {}
    assert cache.stats.hits == 1 and cache.stats.misses == 1  # history kept
    # compile history survives a stats-keeping clear: rebuilding a key
    # compiled before the clear is still a recompile
    cache.get(1, lambda: "x")
    assert cache.stats.recompiles == 1
    cache.clear(reset_stats=True)
    assert cache.stats.hits == 0 and cache.stats.misses == 0
    assert cache.stats.compile_seconds == 0.0
    cache.get(1, lambda: "x")   # history reset: a first compile again
    assert cache.stats.recompiles == 0


def test_global_stats_aggregate_new_fields():
    reset_global_caches()
    a = CompileCache(name="agg-a", capacity=1)
    b = CompileCache(name="agg-b")
    a.get(1, lambda: 1)
    a.get(2, lambda: 2)   # evict 1
    a.get(1, lambda: 1)   # recompile
    b.get("k", lambda: 0)
    g = global_cache_stats()
    assert g["buckets_live"] == 2         # one in each cache
    assert g["recompiles"] == 1
    assert g["evictions"] == 2
    assert set(g["caches"]) == {"agg-a", "agg-b"}


def test_deregister_removes_from_global_stats():
    reset_global_caches()
    c = CompileCache(name="tmp")
    c.get(1, lambda: 1)
    assert "tmp" in global_cache_stats()["caches"]
    c.deregister()
    assert "tmp" not in global_cache_stats()["caches"]
    # still functions as a cache
    assert c.get(1, lambda: 2) == 1


def test_concurrent_precompile_then_step_loop():
    """The replan flow: a background thread precompiles fresh buckets
    (off-thread XLA) while the training loop keeps hitting its own; after
    the swap boundary the loop's first get() on the new bucket must be a
    HIT — never a second compile."""
    import threading
    import time

    cache = CompileCache(name="replan-threads")
    built = []

    def build(key):
        def _b():
            time.sleep(0.005)           # a "compile"
            built.append(key)
            return ("exe", key)
        return _b

    fresh = [f"bucket-{i}" for i in range(4)]
    t = threading.Thread(
        target=lambda: [cache.get(k, build(k)) for k in fresh])
    t.start()
    # the loop keeps stepping its incumbent bucket concurrently
    for _ in range(50):
        cache.get("incumbent", build("incumbent"))
    t.join(timeout=30)
    assert not t.is_alive(), "precompile thread deadlocked"
    # swap boundary: every precompiled bucket is now a resident hit
    before = cache.stats.misses
    for k in fresh:
        assert cache.get(k, build(k)) == ("exe", k)
    assert cache.stats.misses == before, "post-swap get must not compile"
    assert sorted(set(built)) == sorted(fresh + ["incumbent"])
    assert cache.stats.recompiles == 0


def test_concurrent_cold_hammer_converges():
    """Many threads racing cold gets over a small key set: no deadlock,
    every caller gets a live value, and the cache converges to one
    resident entry per key (duplicate racing builds are allowed — the
    docstring's 'first insert wins' — but they stay bounded by the race
    window, never grow per call)."""
    import threading
    import time

    cache = CompileCache(name="hammer-threads")
    keys = [f"k{i}" for i in range(6)]
    calls_per_thread, n_threads = 30, 8
    errors = []

    def worker(seed):
        try:
            for i in range(calls_per_thread):
                k = keys[(seed + i) % len(keys)]
                v = cache.get(k, lambda k=k: (time.sleep(0.002), k)[1])
                assert v == k
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not any(t.is_alive() for t in threads), "hammer deadlocked"
    assert not errors, errors
    st = cache.stats
    assert st.buckets_live == len(keys)
    total = n_threads * calls_per_thread
    assert st.hits + st.misses + st.warm_hits == total
    # duplicate builds only from the initial race window
    assert st.misses <= n_threads * len(keys)
    assert st.hits >= total - n_threads * len(keys)
