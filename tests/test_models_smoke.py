"""Per-architecture smoke tests (reduced configs, CPU, fp32): one forward +
one grad step, shape and finiteness assertions, plus the core EPP property —
processing a sequence as split chunks with the context carry must equal the
monolithic forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_arch
from repro.models import DecoderLM, EncDecLM
from repro.models.frontends import (audio_frame_stub, mrope_positions_stub,
                                    vision_patch_stub)

jax.config.update("jax_enable_x64", False)

T = 96          # packed tokens per chunk in smoke tests
DTYPE = jnp.float32


def _packed_batch(key, vocab, t=T):
    """Two packed sequences: lengths 60 + (t-60)."""
    tokens = jax.random.randint(key, (t,), 0, vocab)
    seg = jnp.where(jnp.arange(t) < 60, 0, 1)
    pos = jnp.where(jnp.arange(t) < 60, jnp.arange(t), jnp.arange(t) - 60)
    targets = jnp.roll(tokens, -1)
    return tokens, targets, seg, pos


@pytest.mark.parametrize("arch", arch_names())
def test_smoke_forward_and_grad(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    tokens, targets, seg, pos = _packed_batch(key, cfg.spec.vocab)

    if cfg.spec.is_encoder_decoder:
        model = EncDecLM(cfg)
        params = model.init(key, DTYPE)
        frames = audio_frame_stub(cfg, key, 64, DTYPE)
        seg_enc = jnp.where(jnp.arange(64) < 40, 0, 1)
        pos_enc = jnp.where(jnp.arange(64) < 40, jnp.arange(64),
                            jnp.arange(64) - 40)

        def loss_fn(p):
            s, n = model.loss(p, frames, seg_enc, pos_enc, tokens, targets,
                              seg, pos, compute_dtype=DTYPE)
            return s / n
    else:
        model = DecoderLM(cfg)
        params = model.init(key, DTYPE)
        pos3 = None
        if cfg.rope_kind == "mrope":
            pos3 = jnp.stack([pos, pos, pos])

        def loss_fn(p):
            s, n = model.loss(p, tokens, targets, seg, pos,
                              positions3=pos3, compute_dtype=DTYPE)
            return s / n

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # a fresh model should predict near-uniform: loss ~ log(vocab)
    assert 0.2 * np.log(cfg.spec.vocab) < float(loss) < 2.5 * np.log(cfg.spec.vocab)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g))), f"{arch}: non-finite grad"
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), \
        f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", [a for a in arch_names()
                                  if not get_arch(a).spec.is_encoder_decoder])
def test_split_chunk_context_equivalence(arch):
    """EPP's token-level PP correctness: forward of [0:T/2] then [T/2:T] with
    the context carry == monolithic forward of [0:T]."""
    cfg = get_arch(arch).reduced()
    model = DecoderLM(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, DTYPE)
    t = 64
    tokens = jax.random.randint(key, (t,), 0, cfg.spec.vocab)
    seg = jnp.zeros((t,), jnp.int32)       # one sequence
    pos = jnp.arange(t)
    pos3 = jnp.stack([pos, pos, pos]) if cfg.rope_kind == "mrope" else None

    full, _ = model.forward_chunk(params, tokens, seg, pos,
                                  positions3=pos3, compute_dtype=DTYPE)

    half = t // 2
    cap = t
    ctx = model.init_ctx(cap, DTYPE)
    h1, ctx = model.forward_chunk(
        params, tokens[:half], seg[:half], pos[:half], ctx=ctx, ctx_len=0,
        positions3=None if pos3 is None else pos3[:, :half],
        compute_dtype=DTYPE)
    h2, _ = model.forward_chunk(
        params, tokens[half:], seg[half:], pos[half:], ctx=ctx, ctx_len=half,
        positions3=None if pos3 is None else pos3[:, half:],
        compute_dtype=DTYPE)
    chunked = jnp.concatenate([h1, h2], axis=0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=2e-4, atol=2e-4)


def test_gemma3_local_global_pattern():
    cfg = get_arch("gemma3-1b")
    ws = cfg.layer_windows()
    assert len(ws) == 26
    assert ws[5] == 0 and ws[11] == 0          # every 6th layer global
    assert all(w == 512 for i, w in enumerate(ws) if (i % 6) != 5)


def test_mrope_vision_positions():
    cfg = get_arch("qwen2-vl-7b").reduced()
    model = DecoderLM(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key, DTYPE)
    n_patch, n_text = 16, 32
    pos3 = mrope_positions_stub(n_text, n_patch, (4, 4))
    tokens = jax.random.randint(key, (n_patch + n_text,), 0, cfg.spec.vocab)
    seg = jnp.zeros((n_patch + n_text,), jnp.int32)
    pos = jnp.arange(n_patch + n_text)
    # patch embeddings replace the token embeddings for the image span
    x = model.embed(params, tokens, DTYPE)
    patches = vision_patch_stub(cfg, key, n_patch, DTYPE)
    x = x.at[:n_patch].set(patches)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    from repro.models import LayerCtx
    ctx = LayerCtx(None, None, None, None)

    def body(x, per):
        lp, w, lctx = per
        x, _ = model.layer_apply(lp, x, pos=pos, seg=seg, ctx=lctx,
                                 ctx_len=jnp.int32(0), window=w,
                                 positions3=pos3)
        return x, None

    out, _ = jax.lax.scan(body, x, (params["layers"], windows, ctx))
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))


def test_mamba_segment_reset_blocks_leakage():
    """Packed mamba: tokens of segment 1 must be unaffected by segment 0."""
    cfg = get_arch("falcon-mamba-7b").reduced()
    model = DecoderLM(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key, DTYPE)
    t = 48
    k1, k2, k3 = jax.random.split(key, 3)
    tok_a = jax.random.randint(k1, (24,), 0, cfg.spec.vocab)
    tok_b = jax.random.randint(k2, (24,), 0, cfg.spec.vocab)
    tok_c = jax.random.randint(k3, (24,), 0, cfg.spec.vocab)
    seg = jnp.where(jnp.arange(t) < 24, 0, 1)
    pos = jnp.where(jnp.arange(t) < 24, jnp.arange(t), jnp.arange(t) - 24)

    h_ab, _ = model.forward_chunk(params, jnp.concatenate([tok_a, tok_b]),
                                  seg, pos, compute_dtype=DTYPE)
    h_cb, _ = model.forward_chunk(params, jnp.concatenate([tok_c, tok_b]),
                                  seg, pos, compute_dtype=DTYPE)
    np.testing.assert_allclose(np.asarray(h_ab[24:]), np.asarray(h_cb[24:]),
                               rtol=1e-5, atol=1e-5)
