"""ILP solver tests: simplex correctness, greedy feasibility, and exactness
of branch-and-bound vs brute force on small covering instances."""

import itertools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import greedy_cover, simplex_lp, solve_cover_ilp


def _brute_force(A, b, ub):
    best = None
    ranges = [range(int(u) + 1) for u in ub]
    for x in itertools.product(*ranges):
        xa = np.array(x, dtype=float)
        if (A @ xa - b >= -1e-9).all():
            s = xa.sum()
            if best is None or s < best:
                best = s
    return best


def test_simplex_known_lp():
    # min x0 + x1  s.t. 2x0 + x1 >= 4, x0 + 3x1 >= 6, 0<=x<=10
    A = np.array([[2.0, 1.0], [1.0, 3.0]])
    b = np.array([4.0, 6.0])
    status, x, obj = simplex_lp(np.ones(2), A, b, np.full(2, 10.0))
    assert status == "optimal"
    # optimum at intersection: x = (6/5, 8/5), obj = 14/5
    assert np.isclose(obj, 14.0 / 5.0, atol=1e-7)
    assert (A @ x - b >= -1e-7).all()


def test_simplex_infeasible():
    # x0 >= 5 with ub 2 => infeasible
    status, x, obj = simplex_lp(np.ones(1), np.array([[1.0]]),
                                np.array([5.0]), np.array([2.0]))
    assert status == "infeasible"


def test_greedy_cover_feasible():
    rng = np.random.default_rng(3)
    A = rng.uniform(0, 2, size=(6, 5))
    b = rng.uniform(1, 4, size=6)
    ub = np.full(5, 10.0)
    x = greedy_cover(A, b, ub)
    assert x is not None
    assert (A @ x - b >= -1e-9).all()
    assert (x <= ub + 1e-9).all() and (x >= -1e-9).all()


def test_ilp_trivial_cases():
    r = solve_cover_ilp(np.zeros((0, 3)), np.zeros(0), np.full(3, 5.0))
    assert r.status == "optimal" and r.objective == 0
    # satisfied constraints only
    r = solve_cover_ilp(np.array([[1.0, 1.0]]), np.array([-3.0]),
                        np.full(2, 5.0))
    assert r.status == "optimal" and r.objective == 0


def test_ilp_infeasible():
    r = solve_cover_ilp(np.array([[1.0]]), np.array([10.0]), np.array([3.0]))
    assert r.status == "infeasible"


def test_ilp_matches_brute_force_fixed():
    A = np.array([[1.0, 0.0, 2.0],
                  [0.0, 1.0, 1.0],
                  [1.0, 1.0, 0.0]])
    b = np.array([3.0, 2.0, 2.0])
    ub = np.array([3.0, 3.0, 3.0])
    r = solve_cover_ilp(A, b, ub, gap=0.0)
    expect = _brute_force(A, b, ub)
    assert r.status in ("optimal", "feasible")
    assert r.objective == expect
    assert (A @ r.x - b >= -1e-9).all()


@given(st.integers(min_value=1, max_value=4),     # vars
       st.integers(min_value=1, max_value=5),     # constraints
       st.integers(min_value=0, max_value=10**6)) # seed
@settings(max_examples=60, deadline=None)
def test_ilp_matches_brute_force_random(nv, nc, seed):
    rng = np.random.default_rng(seed)
    A = rng.integers(0, 4, size=(nc, nv)).astype(float)
    b = rng.integers(0, 8, size=nc).astype(float)
    ub = rng.integers(1, 4, size=nv).astype(float)
    r = solve_cover_ilp(A, b, ub, gap=0.0)
    expect = _brute_force(A, b, ub)
    if expect is None:
        assert r.status == "infeasible"
    else:
        assert r.x is not None
        assert (A @ r.x - b >= -1e-7).all()
        assert (r.x <= ub + 1e-9).all()
        # exact optimality required at gap=0 (integral objective)
        assert r.objective == pytest.approx(expect)
