"""Benchmark entry point: one function per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
the full per-figure records.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json-out", default="",
                    help="also write the full per-figure records (incl. the "
                         "compile_cache stats block) to this JSON file — "
                         "CI uploads it as an artifact")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed stamped into the artifact meta (benches "
                         "that sample take it from here)")
    args = ap.parse_args()

    from . import paper_figures as pf

    figs = {
        "fig7_end_to_end": lambda: pf.fig7_end_to_end(
            batch=48 if args.quick else 96),
        "fig8_breakdown": pf.fig8_breakdown,
        "fig9_scalability": pf.fig9_scalability,
        "fig10_ablation": pf.fig10_ablation,
        "fig11_cost_model_accuracy": pf.fig11_cost_model_accuracy,
        "fig12_solver_scaling": pf.fig12_solver_scaling,
        "fig13_convergence": pf.fig13_convergence,
        "cache_bucket_reuse": lambda: pf.cache_bucket_reuse(
            steps=8 if args.quick else 24),
        "ckpt_policy": lambda: pf.ckpt_policy_compare(
            batch=32 if args.quick else 64),
        "pipeline_bubble": pf.pipeline_bubble,
        "sp_axis": lambda: pf.sp_axis(quick=args.quick),
        "serving_engine": lambda: __import__(
            "benchmarks.serving", fromlist=["serving_engine"]
        ).serving_engine(quick=args.quick),
        "paged_kv": lambda: __import__(
            "benchmarks.serving", fromlist=["paged_kv"]
        ).paged_kv(quick=args.quick),
        "replan": lambda: __import__(
            "benchmarks.replan", fromlist=["replan_drift"]
        ).replan_drift(quick=args.quick),
    }
    only = {x.strip() for x in args.only.split(",") if x.strip()}

    print("name,us_per_call,derived")
    all_rows = {}
    elapsed_s = {}
    for name, fn in figs.items():
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
            status = "ok"
        except Exception as e:  # noqa: BLE001
            rows = [{"error": repr(e)}]
            status = "error"
        dt = (time.perf_counter() - t0) * 1e6
        elapsed_s[name] = round(dt / 1e6, 3)
        derived = _derived(name, rows) if status == "ok" else status
        print(f"{name},{dt:.0f},{derived}", flush=True)
        all_rows[name] = rows

    # roofline summary (reads the dry-run artifacts if present)
    t0 = time.perf_counter()
    try:
        from .roofline import load_cells
        rows = load_cells()
        ok = [r for r in rows if r.status == "ok"]
        best = max((r.frac_of_roofline for r in ok), default=0)
        derived = (f"cells={len(rows)};ok={len(ok)};"
                   f"best_frac={best:.2f}")
    except Exception as e:  # noqa: BLE001
        derived = f"unavailable({e!r})"
    print(f"roofline,{(time.perf_counter() - t0) * 1e6:.0f},{derived}")

    # compile-cache statistics across every step built this process
    t0 = time.perf_counter()
    try:
        from repro.launch.analysis import (compile_cache_report,
                                           format_cache_report)
        cache_stats = compile_cache_report()
        derived = format_cache_report(cache_stats)
    except Exception as e:  # noqa: BLE001
        cache_stats = {"error": repr(e)}
        derived = f"unavailable({e!r})"
    print(f"compile_cache,{(time.perf_counter() - t0) * 1e6:.0f},{derived}")
    all_rows["compile_cache"] = [cache_stats]

    print("\n=== full records ===")
    for name, rows in all_rows.items():
        for r in rows:
            print(json.dumps({"bench": name, **r}))
    if args.json_out:
        # provenance: a BENCH artifact must say WHEN it was measured and
        # with WHICH seed, or two checked-in generations can't be compared
        all_rows["meta"] = [{
            "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "generated_at_unix": round(time.time(), 3),
            "seed": args.seed,
            "quick": bool(args.quick),
            "elapsed_s": elapsed_s,
        }]
        with open(args.json_out, "w") as f:
            json.dump(all_rows, f, indent=1)


def _derived(name: str, rows) -> str:
    if name.startswith("fig7"):
        sp = [r["speedup_vs_flexsp"] for r in rows]
        return f"max_speedup_vs_flexsp={max(sp):.2f}x"
    if name.startswith("fig8"):
        return f"bubble={rows[0]['bubble_ratio']:.3f}"
    if name.startswith("fig9"):
        return f"rows={len(rows)}"
    if name.startswith("fig10"):
        rel = [r["relative"] for r in rows if isinstance(r["relative"], float)]
        return f"worst_variant={max(rel):.2f}x" if rel else "n/a"
    if name.startswith("fig11"):
        errs = [r["error"] for r in rows if "error" in r]
        return f"max_err={max(errs):.3f}" if errs else "n/a"
    if name.startswith("fig12"):
        return f"overlapped={all(r['overlapped'] for r in rows)}"
    if name.startswith("fig13"):
        return str(rows[-1]["loss"])
    if name.startswith("ckpt_policy"):
        by = {r["ckpt_policy"]: r for r in rows}
        sa, un = by["stage-aware"], by["uniform"]
        ratio = (sa["recompute_s"] / un["recompute_s"]
                 if un["recompute_s"] else 1.0)
        return (f"stage_aware_recompute_vs_uniform={ratio:.2f}x;"
                f"layers={sa['ckpt_layers']}vs{un['ckpt_layers']};"
                f"fits={sa['fits_memory']}")
    if name.startswith("paged_kv"):
        by = {r["row"]: r for r in rows}
        pc, cc = by["prefix_cache"], by["concurrency"]
        return (f"prefill_saving={pc['prefill_saving_frac']:.2f};"
                f"bitwise={pc['outputs_bitwise_equal']};"
                f"concurrency={cc['peak_concurrent_seqs']}"
                f"vs{cc['equiv_slots']}slots")
    if name.startswith("serving"):
        by = {r["prefill_mode"]: r for r in rows}
        il, se = by["interleaved"], by["serial"]
        blowup = (se["tpot_s_p95"] / il["tpot_s_p95"]
                  if il["tpot_s_p95"] else 1.0)
        return (f"serial_tpot_p95_vs_interleaved={blowup:.2f}x;"
                f"tok_s={il['tokens_per_s']};"
                f"occ={il['kv_occupancy']:.2f};"
                f"accept={il['spec_acceptance']:.2f}")
    if name.startswith("pipeline_bubble"):
        by = {r["schedule"]: r for r in rows}
        zb, fb = by["zero-bubble-h1"], by["gpipe-1f1b"]
        return (f"zb_realized={zb['realized_bubble']:.2f}"
                f"vs1f1b={fb['realized_bubble']:.2f};"
                f"zb_over_model={zb['realized_over_model']:.3f};"
                f"zb_speedup={zb['speedup_vs_1f1b']:.3f}x")
    if name.startswith("sp_axis"):
        by = {r["mix"]: r for r in rows}
        chk = by["check"]
        return (f"short={chk['short'][0]}@{chk['short'][1]};"
                f"long={chk['long'][0]}@{chk['long'][1]};"
                f"distinct={chk['distinct_sp_points']};"
                f"pin_bucket={by['short_uniform+pin']['pin_distinct_bucket']}")
    if name.startswith("replan"):
        r = rows[0]
        return (f"win={r['steady_state_win']:.3f};"
                f"swaps={r['swaps']}@{r['swap_step']};"
                f"fresh_in_tail={r['fresh_compiles_in_steady_state']};"
                f"comm_delta={r['meta']['calibration_deltas'].get('comm', 0)}")
    if name.startswith("cache"):
        summaries = [r for r in rows
                     if str(r.get("step", "")).startswith("summary")]
        return ";".join(f"q{s['cap_quantum']}:hit={s['hit_rate']:.2f}"
                        f",pad={s['padded_token_frac']:.2f}"
                        for s in summaries)
    return "ok"


if __name__ == "__main__":
    main()
