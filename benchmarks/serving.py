"""Serving-engine benchmarks on the paged, prefix-cached KV pool.

``serving_engine`` contrasts chunked prefill co-scheduled with decode vs
naive stop-the-world prefill on a skewed ("github" preset) request trace.
``paged_kv`` is the acceptance row for the paged pool itself: a shared
system-prompt trace must feed >= 40% fewer prefill tokens with the prefix
cache on than off while emitting bitwise-identical outputs, and a
mixed-length trace must admit strictly more concurrent requests than the
old slot pool could at equal device memory (a slot pool pins
``context_limit + max_new`` rows per admitted request; pages are charged
per token actually held).

Runs ``repro.launch.serve`` in a subprocess per mode (the driver owns the
fake-device XLA flags; the benchmark process keeps its single CPU device
per the harness contract) and reads the ``--stats-json`` artifact.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Sequence

__all__ = ["paged_kv", "serving_engine"]


def _run_serve(tag: str, extra: Sequence[str], *, n_req: int) -> Dict:
    with tempfile.TemporaryDirectory() as td:
        stats = os.path.join(td, f"serve-{tag}.json")
        cmd = [sys.executable, "-m", "repro.launch.serve",
               "--arch", "gemma3-1b", "--reduced",
               "--trace", "github", "--requests", str(n_req),
               "--context-limit", "96", "--max-new", "8",
               "--stats-json", stats, *extra]
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", "src")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            raise RuntimeError(f"serve driver failed ({tag}): "
                               f"{r.stderr[-2000:]}")
        with open(stats) as f:
            return json.load(f)


def _run_mode(mode: str, *, quick: bool) -> Dict:
    n_req = 16 if quick else 32
    # --passes 2 and read the WARM pass: pass 0's TTFT/tokens-per-s are
    # dominated by the one-time XLA engine compile, which would drown the
    # scheduling signal this row exists to measure
    out = _run_serve(f"mode-{mode}", [
        "--arrival-rate", "3.0", "--k", "2",
        "--items", "4", "--cap-t", "32", "--page-sz", "16",
        "--prefill-mode", mode, "--passes", "2"], n_req=n_req)
    return out["passes"][1]


def serving_engine(quick: bool = True) -> List[Dict]:
    rows = []
    for mode in ("interleaved", "serial"):
        st = _run_mode(mode, quick=quick)
        rows.append({
            "prefill_mode": mode,
            "completed": st["completed"],
            "steps": st["steps"],
            "tokens_per_s": st["tokens_per_s"],
            "ttft_s_p50": st["ttft_s_p50"],
            "ttft_s_p95": st["ttft_s_p95"],
            "ttft_steps_p95": st["ttft_steps_p95"],
            "tpot_s_p50": st["tpot_s_p50"],
            "tpot_s_p95": st["tpot_s_p95"],
            "kv_occupancy": st["kv_pool"]["mean_occupancy"],
            "kv_peak_pages": st["kv_pool"]["peak_in_use"],
            "spec_acceptance": st["speculative"]["acceptance_rate"],
            "spec_tokens_per_tick": st["speculative"]["tokens_per_tick"],
            "fresh_compiles": st["fresh_compiles"],
        })
    return rows


def paged_kv(quick: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    # --- prefix cache: shared system prompt, cache on vs off ------------
    n_req = 12 if quick else 24
    common = ["--system-prompt", "48", "--arrival-rate", "0.5",
              "--items", "4", "--cap-t", "32", "--page-sz", "16",
              "--seed", "1"]
    on = _run_serve("prefix-on", common, n_req=n_req)["passes"][0]
    off = _run_serve("prefix-off", common + ["--no-prefix-cache"],
                     n_req=n_req)["passes"][0]
    fed_on = on["prefill_tokens_fed"]
    fed_off = off["prefill_tokens_fed"]
    saving = (fed_off - fed_on) / max(fed_off, 1)
    outputs_equal = on["outputs"] == off["outputs"]
    row = {
        "row": "prefix_cache",
        "requests": n_req,
        "system_prompt_tokens": 48,
        "prefill_fed_cache_on": fed_on,
        "prefill_fed_cache_off": fed_off,
        "prefill_saving_frac": round(saving, 4),
        "prefix_hit_rows": on["kv_pool"]["prefix_hit_rows"],
        "prefix_hit_pages": on["kv_pool"]["prefix_hit_pages"],
        "cow_copies": on["kv_pool"]["cow_copies"],
        "outputs_bitwise_equal": outputs_equal,
    }
    assert outputs_equal, "prefix cache changed the emitted ids"
    assert on["kv_pool"]["prefix_hit_rows"] > 0, "no prefix hits"
    assert saving >= 0.40, f"prefill saving {saving:.2%} < 40%"
    rows.append(row)
    # --- concurrency at equal device memory -----------------------------
    # the old slot pool pinned (context_limit + max_new) = 104 rows per
    # admitted request; give the paged pool the memory of FOUR such slots
    # (416 rows = 26 pages of 16) and pile up a skewed trace — peak
    # concurrent page tables must beat the 4-request slot ceiling
    equiv_slots = 4
    st = _run_serve("concurrency", [
        "--arrival-rate", "8.0", "--pages", "26", "--page-sz", "16",
        "--items", "4", "--cap-t", "32", "--seed", "3"],
        n_req=16 if quick else 32)["passes"][0]
    peak = st["kv_pool"]["peak_seqs"]
    row = {
        "row": "concurrency",
        "pool_rows": 26 * 16,
        "equiv_slots": equiv_slots,
        "peak_concurrent_seqs": peak,
        "peak_pages": st["kv_pool"]["peak_in_use"],
        "mean_occupancy": st["kv_pool"]["mean_occupancy"],
        "preemptions": st["kv_pool"]["preemptions"],
        "completed": st["completed"],
    }
    assert peak > equiv_slots, (
        f"paged pool admitted {peak} concurrent <= slot-equivalent "
        f"{equiv_slots} at equal memory")
    rows.append(row)
    return rows
