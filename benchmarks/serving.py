"""Serving-engine benchmark: chunked prefill co-scheduled with decode vs
naive stop-the-world prefill, on a skewed ("github" preset) request trace.

Runs ``repro.launch.serve`` in a subprocess per mode (the driver owns the
fake-device XLA flags; the benchmark process keeps its single CPU device
per the harness contract) and reads the ``--stats-json`` artifact. Rows
surface tokens/s, TTFT/TPOT percentiles, KV-slot occupancy and the
speculative acceptance rate; the derived headline is the stop-the-world
TPOT-p95 blowup the interleaved scheduler avoids.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List

__all__ = ["serving_engine"]


def _run_mode(mode: str, *, quick: bool) -> Dict:
    n_req = 16 if quick else 32
    with tempfile.TemporaryDirectory() as td:
        stats = os.path.join(td, f"serve-{mode}.json")
        # --passes 2 and read the WARM pass: pass 0's TTFT/tokens-per-s
        # are dominated by the one-time XLA engine compile, which would
        # drown the scheduling signal this row exists to measure
        cmd = [sys.executable, "-m", "repro.launch.serve",
               "--arch", "gemma3-1b", "--reduced",
               "--trace", "github", "--requests", str(n_req),
               "--context-limit", "96", "--max-new", "8",
               "--arrival-rate", "3.0", "--k", "2",
               "--items", "4", "--cap-t", "32", "--slots", "6",
               "--prefill-mode", mode, "--passes", "2",
               "--stats-json", stats]
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", "src")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1200)
        if r.returncode != 0:
            raise RuntimeError(f"serve driver failed ({mode}): "
                               f"{r.stderr[-2000:]}")
        with open(stats) as f:
            return json.load(f)["passes"][1]


def serving_engine(quick: bool = True) -> List[Dict]:
    rows = []
    for mode in ("interleaved", "serial"):
        st = _run_mode(mode, quick=quick)
        rows.append({
            "prefill_mode": mode,
            "completed": st["completed"],
            "steps": st["steps"],
            "tokens_per_s": st["tokens_per_s"],
            "ttft_s_p50": st["ttft_s_p50"],
            "ttft_s_p95": st["ttft_s_p95"],
            "ttft_steps_p95": st["ttft_steps_p95"],
            "tpot_s_p50": st["tpot_s_p50"],
            "tpot_s_p95": st["tpot_s_p95"],
            "kv_occupancy": st["kv_pool"]["mean_occupancy"],
            "kv_peak_slots": st["kv_pool"]["peak_in_use"],
            "spec_acceptance": st["speculative"]["acceptance_rate"],
            "spec_tokens_per_tick": st["speculative"]["tokens_per_tick"],
            "fresh_compiles": st["fresh_compiles"],
        })
    return rows
