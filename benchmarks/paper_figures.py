"""One benchmark per paper table/figure (run via ``python -m benchmarks.run``).

All figures run on the paper's cluster model (4x8 A800) through the
cycle-accurate simulator + cost model — the CPU-only analogue of the paper's
GPU measurements. fig11/fig13 additionally touch real execution.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.llama_paper import (llama_7b, llama_13b, llama_30b,
                                       paper_cluster)
from repro.core import (ClusterSpec, CostModel, ModelSpec, PipelineSimulator,
                        PlannerConfig, backward_order, chunk_sequences,
                        fit_coefficients, plan_batch)
from repro.data import sample_lengths

from .baselines import BASELINES

# strong refs for benchmark-local CompileCaches (the cache registry is weak)
_LIVE_BENCH_CACHES: list = []


def _cm(arch_cfg, ce_mode="inplace", **kw):
    return CostModel(arch_cfg.spec, paper_cluster(**kw), ce_mode=ce_mode)


def fig7_end_to_end(batch=96, seed=0) -> List[Dict]:
    """Iteration time: models x datasets x context lengths, all systems."""
    rows = []
    for model_name, cfg in (("7B", llama_7b()), ("13B", llama_13b())):
        for dataset in ("commoncrawl", "github"):
            for ctx in (49152, 98304):
                lens = sample_lengths(dataset, batch, ctx, seed)
                cm = _cm(cfg)
                res = {"figure": "fig7", "model": model_name,
                       "dataset": dataset, "ctx": ctx}
                for name, fn in BASELINES.items():
                    t0 = time.perf_counter()
                    res[name] = round(fn(cm, lens), 3)
                    res[f"{name}_bench_s"] = round(time.perf_counter() - t0, 2)
                res["speedup_vs_flexsp"] = round(
                    res["flexsp"] / res["infinipipe"], 2)
                res["speedup_vs_deepspeed"] = round(
                    res["deepspeed_usp"] / res["infinipipe"], 2)
                res["speedup_vs_megatron"] = round(
                    res["megatron"] / res["infinipipe"], 2)
                res["speedup_vs_seq1f1b"] = round(
                    res["seq1f1b"] / res["infinipipe"], 2)
                rows.append(res)
    return rows


def fig8_breakdown(batch=96, ctx=49152, seed=0) -> List[Dict]:
    """Time breakdown of an InfiniPipe iteration (13B)."""
    cfg = llama_13b()
    cm = _cm(cfg)
    lens = sample_lengths("github", batch, ctx, seed)
    plan = plan_batch(cm, lens)
    rows = []
    for i, p in enumerate(plan.pipelines):
        sim = PipelineSimulator(cm, p.chunks, p.f2b, p.n_split, p.ckpt)
        r = sim.run()
        total = r.makespan * cm.cluster.d_p
        rows.append({
            "figure": "fig8", "pipeline": i,
            "makespan_s": round(r.makespan, 3),
            "bubble_ratio": round(r.bubble_ratio, 3),
            "compute_frac": round(r.breakdown["compute"] / total, 3),
            "sp_comm_frac": round(r.breakdown["sp_comm"] / total, 3),
            "p2p_frac": round(r.breakdown["p2p"] / total, 3),
            "recompute_frac": round(r.breakdown["recompute"] / total, 3),
            "bubble_frac": round(r.breakdown["bubble"] / total, 3),
        })
    return rows


def fig9_scalability(seed=0) -> List[Dict]:
    """Token throughput vs context length and vs global batch (13B)."""
    cfg = llama_13b()
    cm = _cm(cfg)
    rows = []
    for ctx in (65536, 131072, 196608):
        lens = sample_lengths("github", 64, ctx, seed)
        t_ip = BASELINES["infinipipe"](cm, lens)
        t_s1 = BASELINES["seq1f1b"](cm, lens)
        t_fx = BASELINES["flexsp"](cm, lens)
        rows.append({"figure": "fig9", "axis": "context", "ctx": ctx,
                     "infinipipe_tok_s": round(sum(lens) / t_ip),
                     "seq1f1b_tok_s": round(sum(lens) / t_s1),
                     "flexsp_tok_s": round(sum(lens) / t_fx)})
    for batch in (32, 64, 128):
        lens = sample_lengths("github", batch, 65536, seed)
        t_ip = BASELINES["infinipipe"](cm, lens)
        rows.append({"figure": "fig9", "axis": "batch", "batch": batch,
                     "infinipipe_tok_s": round(sum(lens) / t_ip)})
    return rows


def fig10_ablation(batch=96, ctx=65536, seed=0) -> List[Dict]:
    """w/o workload-balanced chunking, w/o ckpt, full ckpt (13B)."""
    cfg = llama_13b()
    cm = _cm(cfg)
    lens = sample_lengths("github", batch, ctx, seed)
    variants = {
        "infinipipe": PlannerConfig(),
        "wo_wbc": PlannerConfig(uniform_split=True),
        "wo_ckpt": PlannerConfig(disable_ckpt=True),
        "full_ckpt": PlannerConfig(full_ckpt=True),
    }
    rows = []
    base = None
    for name, pc in variants.items():
        try:
            plan = plan_batch(cm, lens, pc)
            t = plan.est_total_time
        except RuntimeError:
            t = float("inf")   # e.g. w/o ckpt may be memory-infeasible
        if name == "infinipipe":
            base = t
        rows.append({"figure": "fig10", "variant": name,
                     "iter_time_s": round(t, 3) if t != float("inf") else "OOM",
                     "relative": round(t / base, 3) if base and t != float("inf") else "—"})
    return rows


def fig11_cost_model_accuracy() -> List[Dict]:
    """Cost-model error: (a) timing-regression held-out error on real CPU
    executions of a reduced model; (b) memory estimate vs the dry-run
    compiled memory_analysis."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core import Chunk, ChunkKind, Slice
    from repro.models import DecoderLM

    cfg = get_arch("llama3.2-3b").reduced(n_layers=4, d_model=128,
                                          n_heads=4, head_dim=32, vocab=512)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    cm = CostModel(cfg.spec, ClusterSpec(d_p=1, d_s=1,
                                         flops_per_chip=5e10, mfu=1.0))

    def measure(n_tok: int) -> float:
        tok = jnp.zeros((n_tok,), jnp.int32)
        seg = jnp.zeros((n_tok,), jnp.int32)
        pos = jnp.arange(n_tok)
        f = jax.jit(lambda p: model.loss(p, tok, tok, seg, pos,
                                         compute_dtype=jnp.float32)[0])
        f(params).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            f(params).block_until_ready()
        return (time.perf_counter() - t0) / 3

    sizes = [64, 128, 256, 384, 512, 768, 1024]
    samples = []
    for n in sizes:
        ch = Chunk(kind=ChunkKind.BATCHED, context=0,
                   slices=(Slice(0, 0, n, True),))
        samples.append((ch, measure(n)))
    fit = fit_coefficients(cm.coeffs, cm.cluster, samples[:-2])
    cm_fit = CostModel(cfg.spec, cm.cluster, coeffs=fit)
    rows = []
    for (ch, t_meas) in samples[-2:]:       # held out
        t_pred = cm_fit.t_comp(ch) * cm_fit.utilization(ch)
        err = abs(t_pred - t_meas) / t_meas
        rows.append({"figure": "fig11", "kind": "time",
                     "tokens": ch.tokens, "measured_s": round(t_meas, 4),
                     "predicted_s": round(t_pred, 4),
                     "error": round(err, 3)})
    return rows


def fig12_solver_scaling(seed=0) -> List[Dict]:
    """Solver wall time vs cluster scale (batch scales with #GPUs)."""
    cfg = llama_13b()
    rows = []
    for n_gpu, d_p, d_s in ((32, 4, 8), (64, 8, 8), (128, 16, 8)):
        cm = CostModel(cfg.spec, paper_cluster(d_p=d_p, d_s=d_s))
        batch = 128 * (n_gpu // 32)
        lens = sample_lengths("github", batch, 65536, seed)
        t0 = time.perf_counter()
        plan = plan_batch(cm, lens)
        solve = time.perf_counter() - t0
        rows.append({"figure": "fig12", "n_gpu": n_gpu,
                     "solve_s": round(solve, 2),
                     "amortized_s": round(solve / (n_gpu / 8), 2),
                     "iter_time_s": round(plan.est_total_time, 2),
                     "overlapped": bool(solve < plan.est_total_time)})
    return rows


def fig13_convergence(steps=8) -> List[Dict]:
    """Per-token loss: EPP chunked execution == monolithic reference."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core import PlannerConfig
    from repro.data import materialize_plan, sample_corpus_batch
    from repro.models import DecoderLM

    cfg = get_arch("llama3.2-3b").reduced(n_layers=2, d_model=64,
                                          n_heads=4, head_dim=16, vocab=256)
    model = DecoderLM(cfg)
    cm = CostModel(cfg.spec, ClusterSpec(d_p=2, d_s=2))
    rows = []
    params = model.init(jax.random.PRNGKey(0), jnp.float32)

    @jax.jit
    def chunk_grad(p, tok, tgt, seg, pos):
        def f(p):
            s, n = model.loss(p, tok, tgt, seg, pos,
                              compute_dtype=jnp.float32)
            return s, n
        (s, n), g = jax.value_and_grad(f, has_aux=True)(p)
        return s, n, g

    lr = 0.05
    for step in range(steps):
        corpus = sample_corpus_batch("github", 6, 512, cfg.spec.vocab,
                                     seed=step)
        lens = [len(v) for v in corpus.values()]
        plan = plan_batch(cm, lens, PlannerConfig(fixed_k=2,
                                                  bucket_rounding=16))
        cb = materialize_plan(plan, corpus)
        tot = jnp.float32(0)
        cnt = jnp.float32(0)
        acc = jax.tree.map(jnp.zeros_like, params)
        # chunked EPP-order execution with grad accumulation
        for k in range(cb.tokens.shape[0]):
            tok = jnp.maximum(jnp.asarray(cb.tokens[k]), 0)
            s, n, g = chunk_grad(params, tok,
                                 jnp.asarray(cb.targets[k]),
                                 jnp.asarray(cb.seg[k]),
                                 jnp.asarray(cb.pos[k]))
            tot += s
            cnt += n
            acc = jax.tree.map(lambda a, b: a + b, acc, g)
        params = jax.tree.map(lambda p, g: p - lr * g / cnt, params, acc)
        rows.append({"figure": "fig13", "step": step,
                     "loss": round(float(tot / cnt), 4)})
    # convergence: loss decreases
    rows.append({"figure": "fig13", "step": "check",
                 "loss": "decreasing" if rows[-1]["loss"] < rows[0]["loss"]
                 else "NOT-DECREASING"})
    return rows


def cache_bucket_reuse(steps=24, batch=48, ctx=49152, seed=0) -> List[Dict]:
    """Plan-bucket reuse across a training run (§III: bucketed chunk
    geometry => the compiled program is reused). Plans ``steps`` consecutive
    batches, maps each through ``ExecutionPlan.bucket_key`` and a
    :class:`~repro.runtime.compile_cache.CompileCache` with a stub builder —
    the hit rate IS the fraction of steps that skip XLA compilation. Swept
    over the capacity quantum: long-context batches fragment the bucket
    space at fine quanta, so coarser quanta trade masked padding tokens for
    executable reuse."""
    from repro.runtime.compile_cache import CompileCache

    cfg = llama_7b()
    cm = _cm(cfg)
    d_s = cm.cluster.d_s
    quanta = (0, 4096, 16384)  # 0 => the d_s-rounded default
    caches = {q: CompileCache(name=f"bench-bucket-reuse-q{q}")
              for q in quanta}
    # the registry holds caches weakly; keep THIS sweep's caches alive so
    # the process-wide compile_cache row in benchmarks/run.py still sees
    # them, dropping any previous sweep's (no unbounded growth)
    _LIVE_BENCH_CACHES[:] = caches.values()
    slot_tokens = {q: 0 for q in quanta}
    real_tokens = 0
    rows = []
    for step in range(steps):
        lens = sample_lengths("github", batch, ctx, seed + step)
        t0 = time.perf_counter()
        plan = plan_batch(cm, lens, PlannerConfig())
        real_tokens += plan.total_tokens
        row = {"figure": "cache", "step": step,
               "plan_s": round(time.perf_counter() - t0, 3)}
        for q in quanta:
            key = plan.bucket_key(d_s, cap_quantum=q)
            caches[q].get(key, lambda k=key: k)  # stub build
            # BucketKey is a NamedTuple: access by name, never position
            slot_tokens[q] += key.n_chunks * key.cap
            row[f"bucket_q{q}"] = list(key)
        rows.append(row)
    for q in quanta:
        stats = caches[q].stats.as_dict()
        rows.append({"figure": "cache", "step": f"summary_q{q}",
                     "cap_quantum": q, **stats,
                     "distinct_buckets": len(caches[q]),
                     "padded_token_frac": round(
                         1 - real_tokens / max(1, slot_tokens[q]), 4)})
    return rows


def ckpt_policy_compare(batch=64, ctx=65536, seed=0,
                        mem_fraction=None) -> List[Dict]:
    """Stage-aware vs uniform adaptive checkpointing (Eq. 9-11): the
    measurable knob at the end of the per-(stage, chunk) ``l_ckpt``
    refactor. One planned batch, three executor remat policies over the
    SAME chunks/schedule, replayed through the cycle-accurate simulator:

    * ``stage-aware`` — the ILP's per-(stage, chunk) table as solved;
    * ``uniform`` — every (stage, chunk) remats the table's max (the
      pre-vector executor collapse);
    * ``none`` — no recomputation (the memory bound the ILP works under).

    Rows carry recompute seconds, iteration time, checkpointed layer count
    and per-stage peak memory; ``bucket_digest`` shows the compile-cache
    identity each policy lands on — distinct whenever the solved table is
    genuinely non-uniform (a constant table collapses to the uniform
    digest, which is correct aliasing: both compile the same program).
    ``mem_fraction`` tightens the cluster memory to force the ILP to
    checkpoint (default: enough pressure that the table is non-trivial).
    """
    cfg = llama_13b()
    cm = _cm(cfg)
    if mem_fraction is None:
        # tight enough that running without checkpointing does NOT fit and
        # the ILP's per-(stage, chunk) choices visibly beat the uniform
        # collapse (~10x less recompute at batch 64 / 64K ctx)
        mem_fraction = 0.5
    cap_bytes = cm.cluster.capacity_bytes * mem_fraction
    lens = sample_lengths("github", batch, ctx, seed)
    plan = plan_batch(cm, lens, PlannerConfig(remat_mode="stage_aware",
                                              capacity_bytes=cap_bytes))
    d_p = cm.cluster.d_p
    l_max = plan.uniform_ckpt()
    # the REAL cache identity: bucket_key digests the table padded to the
    # rounded bucket chunk count, so report that, not the unpadded form
    digests = {"stage-aware": plan.bucket_key(cm.cluster.d_s).ckpt,
               "uniform": f"u{l_max}", "none": "u0"}
    rows = []
    for policy in ("stage-aware", "uniform", "none"):
        tot = recomp = 0.0
        peak = 0.0
        layers = 0
        for p in plan.pipelines:
            n = len(p.chunks)
            if policy == "stage-aware":
                tab = p.ckpt
            else:
                v = l_max if policy == "uniform" else 0
                tab = [[v] * n for _ in range(d_p)]
            r = PipelineSimulator(cm, p.chunks, p.f2b, p.n_split, tab).run()
            tot += r.makespan
            recomp += r.breakdown["recompute"]
            peak = max(peak, max(r.per_stage_peak_mem, default=0.0))
            layers += sum(sum(row) for row in tab)
        rows.append({"figure": "ckpt_policy", "ckpt_policy": policy,
                     "iter_time_s": round(tot, 3),
                     "recompute_s": round(recomp, 3),
                     "ckpt_layers": layers,
                     "peak_mem_gb": round(peak / 1e9, 3),
                     "fits_memory": bool(peak <= cap_bytes),
                     "bucket_digest": digests[policy]})
    return rows


def sp_axis(quick=False) -> List[Dict]:
    """The planner's sequence-parallel axis: (policy, d_s_eff) chosen per
    length mix (the PR-8 tentpole's measurable knob).

    Two synthetic mixes on the paper cluster bracket the tradeoff:

    * ``short_uniform`` — many tiny sequences. Full SP sharding starves
      the MXU (tokens/device under the half-saturation point), so the
      planner backs the degree off (replicating chunk compute across the
      idle model-axis devices is cheaper than running them all
      unsaturated);
    * ``long_skewed`` — a few 32K-128K documents. Quadratic attention
      dominates and the full axis wins.

    Each row reports the chosen ``(policy, d_s_eff)``, the ranked sweep
    the planner recorded (``meta["sp_sweep"]``), and the bucket-key SP
    fields; the ``check`` row asserts the two mixes land on DIFFERENT SP
    points with different compile-cache identities, and that a pinned
    ``--sp-policy`` plan gets its own bucket (CI gates on it).

    Runs on a mid-size proxy model with a d_p=4 x d_s=4 mesh rather than
    the A800 paper cluster: at 13B-scale flops the paper cluster's
    intra-node bandwidth makes full sharding win for every mix (chunks
    pack sequences, so even all-256-token batches fill chunks past the
    half-saturation point per shard) — the degree tradeoff only opens up
    where per-shard chunk slices drop below saturation.
    """
    spec = ModelSpec(name="sp-proxy", n_layers=8, d_model=512, n_heads=8,
                     n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000)
    cm = CostModel(spec, ClusterSpec(d_p=4, d_s=4))
    d_s = cm.cluster.d_s
    mixes = {
        "short_uniform": [256] * (64 if quick else 256),
        "long_skewed": ([131072, 65536, 32768] + [8192] * 8)
        * (1 if quick else 4),
    }
    rows = []
    chosen = {}
    keys = {}
    for name, lens in mixes.items():
        t0 = time.perf_counter()
        plan = plan_batch(cm, lens, PlannerConfig())
        key = plan.bucket_key(d_s)
        chosen[name] = (plan.sp.policy, plan.sp.d_s_eff)
        keys[name] = key
        rows.append({
            "figure": "sp_axis", "mix": name,
            "tokens": sum(lens), "n_seqs": len(lens),
            "sp_policy": plan.sp.policy, "d_s_eff": plan.sp.d_s_eff,
            "est_time_s": round(plan.est_total_time, 3),
            "solve_s": round(time.perf_counter() - t0, 2),
            "bucket_sp": [key.sp_policy, key.d_s_eff],
            "sweep": {k: (round(v, 3) if v < float("inf") else "inf")
                      for k, v in plan.meta["sp_sweep"].items()},
        })
    pinned = plan_batch(cm, mixes["short_uniform"],
                        PlannerConfig(sp_policy="allgather_kv",
                                      sp_degree=d_s))
    rows.append({
        "figure": "sp_axis", "mix": "short_uniform+pin",
        "sp_policy": pinned.sp.policy, "d_s_eff": pinned.sp.d_s_eff,
        "est_time_s": round(pinned.est_total_time, 3),
        "pin_distinct_bucket":
            bool(pinned.bucket_key(d_s) != keys["short_uniform"]),
    })
    rows.append({
        "figure": "sp_axis", "mix": "check",
        "short": list(chosen["short_uniform"]),
        "long": list(chosen["long_skewed"]),
        "distinct_sp_points":
            bool(chosen["short_uniform"] != chosen["long_skewed"]),
        "distinct_buckets":
            bool(keys["short_uniform"] != keys["long_skewed"]),
    })
    return rows


def pipeline_bubble(n_items=16, d_p=4, t_f=1.0, t_b=2.0,
                    t_w=0.3) -> List[Dict]:
    """Realized executor bubble per schedule backend vs the closed forms —
    the measurable knob of the B/W backward split + double-buffered
    hand-off (runtime/executor.py).

    Three numbers per backend at one geometry:

    * ``model_bubble`` — ``ScheduleSpec.bubble_time``, the free-form
      placement ideal (ZB-H1: ``(d_p-1)(t_f+t_b-2t_w)``);
    * ``realized_bubble`` — ``realized_bubble_time``, what the lockstep
      scan pays with the split compiled in (ZB-H1:
      ``(d_p-1)(t_f+t_b-t_w)`` — the cooldown's garbage B-ticks can't be
      retasked, everything else fills);
    * ``sim_bubble`` — the event-driven simulator's idle time, the
      validation substrate for the model form.

    The default ``t_w/(t_f+t_b) = 0.1`` is the long-context regime the
    paper targets (attention dgrad is O(T^2 d), wgrad only O(T d^2), so
    the weight-grad share shrinks with context) — there the realized
    ZB-H1 bubble sits within 15% of the model closed form and strictly
    below 1F1B's. ``speedup_vs_1f1b`` compares per-stage realized
    makespans (work + realized bubble).
    """
    from repro.core.schedule import get_schedule, simulate_schedule

    work = n_items * (t_f + t_b)
    backends = [("gpipe-1f1b", 1), ("interleaved-1f1b", 2),
                ("zero-bubble-h1", 1)]
    base = get_schedule("gpipe-1f1b").realized_bubble_time(
        n_items, d_p, t_f, t_b, t_w)
    rows = []
    for name, v in backends:
        spec = get_schedule(name, v)
        model = spec.bubble_time(n_items, d_p, t_f, t_b, t_w)
        realized = spec.realized_bubble_time(n_items, d_p, t_f, t_b, t_w)
        sim = simulate_schedule(spec, n_items, d_p, t_f, t_b, t_w)
        rows.append({
            "figure": "pipeline_bubble", "schedule": name, "v": v,
            "n_items": n_items, "d_p": d_p,
            "t_f": t_f, "t_b": t_b, "t_w": t_w,
            "model_bubble": round(model, 6),
            "realized_bubble": round(realized, 6),
            "sim_bubble": round(sim["bubble_time"], 6),
            "model_fraction": round(model / (work + model), 4),
            "realized_fraction": round(realized / (work + realized), 4),
            "realized_over_model": round(realized / model, 4)
            if model > 0 else None,
            "speedup_vs_1f1b": round((work + base) / (work + realized), 4),
        })
    return rows
