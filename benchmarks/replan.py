"""Online re-planning benchmark: the closed telemetry → calibration →
re-solve loop on a two-phase drifting trace, against the static ``--replan
off`` regime.

Planner-level (no XLA): a hidden TRUTH cost model plays the executor —
"measured" step time is the pipeline simulator's makespan under the truth
model. At the phase change the truth drifts in three ways the bootstrap
model knows nothing about: collective bandwidth collapses 16x (network
contention as the long-context phase's KV all-gathers land), stage 3
straggles 1.8x, and the attention coefficient grows 1.35x. The bandwidth
collapse is the economically decisive one: the planner's chosen
``allgather_kv`` sequence-parallel policy becomes a liability, and the
truth-optimal plan flips to ``sp=none`` — a different compile bucket, i.e.
exactly the kind of move only a calibrated re-solve can make.

Arms:

* ``static`` — ``--replan off``: every step re-chunks its batch with the
  UNCALIBRATED base model. Plans ride the length mix but keep trusting the
  stale bandwidth numbers, so phase 2 keeps paying for all-gathers over a
  collapsed fabric.
* ``auto``   — the ReplanController loop exactly as ``launch/train.py``
  wires it: drift (CUSUM) / mix-shift triggers, robust calibration fit,
  hysteresis-gated bucket swap with off-thread precompile, warm-vs-fresh
  compile accounting via a real ``CompileCache``.

Gates (BENCH_replan.json / CI):

* steady-state (last half of phase 2) auto step time >= 10% under static;
* the bucket set CLOSES: zero fresh compiles over the steady-state tail
  and no bucket ever compiled twice (``recompiles == 0``);
* ``meta`` records the calibration deltas that drove the win.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, List

import numpy as np

from repro.configs.llama_paper import llama_7b, paper_cluster
from repro.core import CostModel, PlannerConfig, plan_batch
from repro.core.planner import estimate_plan_time
from repro.data import sample_lengths
from repro.runtime.compile_cache import CompileCache
from repro.telemetry import ReplanConfig, ReplanController

D_P, D_S = 4, 2
BW_COLLAPSE = 16.0                        # collective bandwidth /16, phase 2
SLOW_STAGE, SLOW_FACTOR = 3, 1.8          # stage 3 (1-based) straggles
QUAD_DRIFT = 1.35                         # attention coeff drift, phase 2
N_SEEDS = 3                               # batches cycle over this many mixes


def _truth(base: CostModel, phase: int) -> CostModel:
    """The executor's hidden reality. Phase 2 collapses the collective
    fabric, slows stage 3 and drifts the attention coefficient."""
    if phase == 1:
        return base
    co = replace(base.coeffs,
                 ag_bw=base.coeffs.ag_bw / BW_COLLAPSE,
                 a2a_bw=base.coeffs.a2a_bw / BW_COLLAPSE,
                 alpha1=base.coeffs.alpha1 * QUAD_DRIFT)
    slow = [SLOW_FACTOR if p == SLOW_STAGE else 1.0
            for p in range(1, D_P + 1)]
    return CostModel(base.model, base.cluster, co,
                     stage_slowdowns=slow, ce_mode=base.ce_mode)


def _trace(quick: bool):
    """(step, phase, lengths): short-uniform then long-skewed. Each phase
    cycles over N_SEEDS fixed mixes — enough row diversity for the
    calibration fit to be well-posed, yet a finite recurring bucket set so
    the zero-fresh-compile steady state is reachable."""
    n1 = 6 if quick else 9
    n2 = 12 if quick else 18
    batch = 16
    short = [sample_lengths("uniform", batch, 4096, seed=100 + s)
             for s in range(N_SEEDS)]
    long_ = [sample_lengths("github", batch, 32768, seed=200 + s)
             for s in range(N_SEEDS)]
    out = [(i, 1, short[i % N_SEEDS]) for i in range(n1)]
    out += [(i, 2, long_[i % N_SEEDS]) for i in range(n1, n1 + n2)]
    return out


def replan_drift(quick: bool = False) -> List[Dict]:
    base = CostModel(llama_7b().spec, paper_cluster(d_p=D_P, d_s=D_S))

    def solve(cm, lengths):
        return plan_batch(cm, lengths, PlannerConfig())

    def bucket_of(plan):
        return str(plan.bucket_key(D_S))

    def held_solve(cm, lengths, inc):
        # hysteresis strawman (train.py's resolve_incumbent): this batch
        # re-chunked under the incumbent's bucket — capacity AND sp policy
        # pinned, else the "held" solve silently makes the candidate's move
        key = inc.bucket_key(D_S)
        return plan_batch(cm, lengths,
                          PlannerConfig(token_capacity=key.cap,
                                        sp_policy=key.sp_policy,
                                        sp_degree=key.d_s_eff))

    trace = _trace(quick)

    # --- static arm: --replan off (per-step solves, stale base model) -----
    static_times = [estimate_plan_time(_truth(base, phase),
                                       solve(base, lengths))
                    for _, phase, lengths in trace]

    # --- auto arm: the full controller loop -------------------------------
    cache = CompileCache(name="replan-bench")
    controller = ReplanController(
        base, ReplanConfig(mode="auto", min_samples=3, cooldown_steps=2,
                           background=False),
        solve, bucket_of,
        resolve_incumbent=held_solve,
        precompile=lambda p: cache.get(bucket_of(p), lambda: object()))
    rng = np.random.default_rng(0)
    auto_times = []
    compiles_at_step = []
    swap_step = None
    for step, phase, lengths in trace:
        plan = solve(controller.cost_model(), lengths)
        cache.get(bucket_of(plan), lambda: object())   # execute = hit/compile
        truth = _truth(base, phase)
        wall = estimate_plan_time(truth, plan)
        noisy = wall * (1 + 0.01 * rng.standard_normal())
        stages = [truth.stage_slowdowns[p - 1] if truth.stage_slowdowns
                  else 1.0 for p in range(1, D_P + 1)]
        # comm probe: what a collective-timing hook would report — the
        # collective seconds on the critical path, i.e. the makespan minus
        # the same makespan over an infinitely fast fabric. Same units as
        # the measured wall (raw component work is not)
        nocomm = CostModel(truth.model, truth.cluster,
                           replace(truth.coeffs,
                                   ag_bw=truth.coeffs.ag_bw * 1e9,
                                   a2a_bw=truth.coeffs.a2a_bw * 1e9),
                           stage_slowdowns=truth.stage_slowdowns,
                           ce_mode=truth.ce_mode)
        comm_s = (max(0.0, wall - estimate_plan_time(nocomm, plan))
                  * (1 + 0.02 * rng.standard_normal()))
        controller.observe_step(step, plan, noisy, lengths,
                                per_stage_s=[noisy / D_P * s for s in stages],
                                comm_s=comm_s)
        dec = controller.poll()
        if dec is not None and dec.is_swap and swap_step is None:
            swap_step = step
        # snapshot AFTER poll: a swap's off-thread precompile counts as
        # this step's compile, so "after the swap" means strictly later
        compiles_at_step.append(cache.stats.misses)
        auto_times.append(wall)
    controller.drain()

    # steady state: the last half of phase 2
    p2 = [i for i, (_, ph, _) in enumerate(trace) if ph == 2]
    tail = p2[len(p2) // 2:]
    ss_static = float(np.mean([static_times[i] for i in tail]))
    ss_auto = float(np.mean([auto_times[i] for i in tail]))
    win = 1.0 - ss_auto / ss_static
    fresh_in_tail = compiles_at_step[-1] - compiles_at_step[tail[0] - 1]

    snap = controller.snapshot()
    return [{
        "row": "drift_trace",
        "steps": len(trace),
        "drift_step": p2[0],
        "swap_step": swap_step,
        "swaps": snap["counters"]["swaps"],
        "recalibrations": snap["counters"]["recalibrations"],
        "hysteresis_rejects": snap["counters"]["hysteresis_rejects"],
        "triggers": snap["triggers"],
        "distinct_buckets": cache.stats.misses,
        "recompiles": cache.stats.recompiles,
        "fresh_compiles_in_steady_state": fresh_in_tail,
        "steady_state_static_s": round(ss_static, 4),
        "steady_state_auto_s": round(ss_auto, 4),
        "steady_state_win": round(win, 4),
        "meta": {
            "calibration_version": snap["calibration_version"],
            "calibration_deltas": snap["calibration_deltas"],
            "truth": {"bw_collapse": BW_COLLAPSE,
                      "slow_stage": SLOW_STAGE,
                      "slow_factor": SLOW_FACTOR,
                      "quad_drift": QUAD_DRIFT},
        },
    }]


if __name__ == "__main__":
    import json
    print(json.dumps(replan_drift(quick=True), indent=1))
