"""Roofline analysis over the dry-run artifacts (deliverable g).

Three terms per (arch x shape) cell on the single-pod mesh, in seconds per
training/serving step, per chip:

  compute    = EXEC_FLOPs  / (197e12)       [bf16 peak]
  memory     = HBM_bytes   / (819e9)
  collective = ICI_bytes   / (50e9)         [per-link]

Methodology note (EXPERIMENTS.md §Roofline): XLA's ``cost_analysis`` counts
while-loop bodies ONCE, so for scan-structured programs its flops
drastically under-report. EXEC_FLOPs/HBM_bytes are therefore derived
*analytically from the compiled geometry* — the executor's schedule is
fully known (ticks x stages x layers), every factor (pipeline-bubble
compute, padded layer slots, remat recompute, CE, EP balance) is explicit —
and the dry-run JSON's ``cost_analysis``/``hlo_collectives_static`` fields
are kept as cross-checks. Collective volumes come from the executor's own
collective schedule (``dryrun.analytic_collectives``), exact per step.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per training token;
the MODEL_FLOPS / EXEC_FLOPs ratio surfaces bubble + padding + remat +
lockstep-SPMD waste.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.configs import SHAPES, get_arch
from repro.core.costs import (_act_bytes_per_token,
                              _attn_flops_per_token_pair,
                              _linear_flops_per_token,
                              _local_attn_flops_per_token)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
E = 2  # bf16 bytes


@dataclass
class RooflineRow:
    arch: str
    shape: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_flops: float = 0.0          # aggregate useful flops (per device)
    exec_flops: float = 0.0           # executed flops (per device)
    hlo_flops_static: float = 0.0
    bottleneck: str = ""
    frac_of_roofline: float = 0.0     # model_flops/peak vs step time
    note: str = ""

    def step_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def _layer_body_bytes(s, d_s: int = 16) -> float:
    """Per-device weight bytes READ per layer use (bf16): gathered ZeRO
    leaves are full; EP expert weights stay sharded — each device reads
    only its E/d_s expert shard."""
    body = s.param_count() - s.vocab * s.d_model * (1 if s.tie_embeddings
                                                    else 2)
    n_l = max(s.n_layers + (s.n_encoder_layers or 0), 1)
    expert = 0.0
    if s.n_experts:
        expert = s.n_experts * 3 * s.d_model * s.d_ff_expert
        body -= expert * s.n_layers
    return (body / n_l + expert / d_s) * E


def exec_flops_train(cfg, geom: Dict, shape, n_dev: int,
                     kind: str) -> Tuple[float, float]:
    """(exec_flops_per_device, model_flops_per_device)."""
    s = cfg.spec
    n, cap = geom["n_chunks"], geom["cap"]
    d_p = 16
    L_ps = geom["layers_per_stage"]
    ticks = n + d_p - 1
    total_tokens = shape.seq_len * shape.global_batch  # single pod = all
    # --- useful model flops ---
    lin_tok = _linear_flops_per_token(s) + _local_attn_flops_per_token(s)
    quad_pair = _attn_flops_per_token_pair(s)  # per (q,k) pair, whole model
    quad_total = shape.global_batch * quad_pair * (shape.seq_len ** 2) / 2
    fwd = total_tokens * lin_tok + quad_total
    mult = 3.0 if kind == "train" else 1.0      # fwd + 2x bwd
    model = fwd * mult
    # --- executor overheads ---
    bubble = ticks / max(n, 1)
    pad = (d_p * L_ps) / max(s.n_layers + (s.n_encoder_layers or 0), 1)
    remat = 1.0 + (geom.get("l_ckpt", 0) * d_p
                   / max(s.n_layers, 1)) * (1.0 if kind == "train" else 0.0)
    execf = fwd * mult * bubble * pad * remat
    if cfg.spec.is_encoder_decoder:
        execf *= 2.0  # lockstep enc+dec both execute each tick (DESIGN §8)
    # CE (+bwd): 2*D*V per token x3; prefill: argmax 2*D*V
    vp = ((s.vocab + 15) // 16) * 16
    ce = total_tokens * 2 * s.d_model * vp * (3.0 if kind == "train" else 1.0)
    execf += ce * bubble
    model += total_tokens * 2 * s.d_model * s.vocab * (
        3.0 if kind == "train" else 1.0)
    return execf / n_dev, model / n_dev


def hbm_bytes_train(cfg, geom: Dict, shape, n_dev: int, kind: str) -> float:
    s = cfg.spec
    n, cap = geom["n_chunks"], geom["cap"]
    d_p, d_s = 16, 16
    L_ps = geom["layers_per_stage"]
    ticks = n + d_p - 1
    passes = 2.0 if kind == "train" else 1.0   # fwd + bwd weight reads
    # each tick re-reads the stage's (gathered) layer weights
    w = ticks * L_ps * _layer_body_bytes(s) * passes
    # activations: ~2x (write+read) of per-layer activation bytes
    act_tok = _act_bytes_per_token(s) / n_dev
    acts = (ticks * cap / d_s) * act_tok / max(s.n_layers, 1) \
        * L_ps * 2.0 * passes
    # optimizer: params fp32 master+m+v read+write (train only)
    opt = 0.0
    if kind == "train":
        opt = (s.param_count() / (d_p * d_s)) * (4 + 4 + 4) * 2
    # embedding/head rows + CE streaming weight reads per tick
    vp = ((s.vocab + 15) // 16) * 16
    ce_w = ticks * (vp / d_s) * s.d_model * E * passes
    return w + acts + opt + ce_w


def exec_decode(cfg, geom: Dict, shape, n_dev: int
                ) -> Tuple[float, float, float]:
    """(exec_flops, model_flops, hbm_bytes) per device, one decode step."""
    s = cfg.spec
    d_p, d_s = 16, 16
    nm = geom.get("n_micro", d_p)
    bm = max(1, shape.global_batch // nm)
    L_ps = geom["layers_per_stage"]
    ticks = nm + d_p - 1
    S = shape.seq_len
    # per-token linear flops (active params) + attention cache reads
    lin_tok = _linear_flops_per_token(s)
    n_layers = max(s.n_layers, 1)
    attn = 0.0
    if not s.attn_free:
        for i in range(n_layers):
            w = cfg.layer_window(i)
            span = min(S, w) if w else S
            attn += 4 * s.n_heads * s.head_dim * span
    model = shape.global_batch * (lin_tok + attn)
    bubble = ticks / max(nm, 1)
    pad = (d_p * L_ps) / n_layers
    execf = model * bubble * pad
    vp = ((s.vocab + 15) // 16) * 16
    execf += shape.global_batch * 2 * s.d_model * vp * bubble
    model += shape.global_batch * 2 * s.d_model * s.vocab
    # HBM: weights per tick + KV cache read (the decode bandwidth wall)
    w = ticks * L_ps * _layer_body_bytes(s) + (vp / d_s) * s.d_model * E
    kv = 0.0
    if not s.attn_free:
        for i in range(n_layers):
            wdw = cfg.layer_window(i)
            span = min(S, wdw) if wdw else S
            kv += bm * nm * (span / d_s) * 2 * s.d_kv * E / d_p * bubble
    if s.ssm_state:
        kv += nm * L_ps * bm * s.inner * s.ssm_state * 4 * 2
    return execf / n_dev, model / n_dev, w + kv


def analyze_cell(rec: Dict) -> RooflineRow:
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    row = RooflineRow(arch=arch, shape=shape_name, status=rec["status"])
    if rec["status"] != "ok":
        row.note = rec.get("reason", rec.get("error", ""))[:90]
        return row
    n_dev = rec.get("n_devices", 256)
    geom = rec["geometry"]
    # recompute collective volumes from geometry (keeps accounting fixes in
    # one place — no recompiles needed); the recorded value is the original
    from types import SimpleNamespace

    from repro.launch.analysis import analytic_collectives
    g = SimpleNamespace(d_p=16, d_s=16, **{k: v for k, v in geom.items()})
    if shape.kind == "decode" and not hasattr(g, "bm"):
        g.bm = max(1, shape.global_batch // g.n_micro)
    if not hasattr(g, "zero3_mode"):
        g.zero3_mode = ("per_step" if rec.get("note") == "zero3step"
                        else "per_tick")
    coll = analytic_collectives(cfg, g, shape.kind)
    if shape.kind in ("train", "prefill"):
        execf, model = exec_flops_train(cfg, geom, shape, n_dev, shape.kind)
        hbm = hbm_bytes_train(cfg, geom, shape, n_dev, shape.kind)
    else:
        execf, model, hbm = exec_decode(cfg, geom, shape, n_dev)
    row.exec_flops = execf
    row.model_flops = model
    row.hlo_flops_static = rec.get("flops", 0.0)
    row.compute_s = execf / PEAK_FLOPS
    row.memory_s = hbm / HBM_BW
    row.collective_s = (coll.get("ici_bytes", 0.0)
                        + coll.get("p2p_bytes", 0.0)) / ICI_BW
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.bottleneck = max(terms, key=terms.get)
    ideal = model / PEAK_FLOPS
    row.frac_of_roofline = ideal / max(row.step_time(), 1e-30)
    return row


def load_cells(run_dir: str = "runs/dryrun", mesh: str = "16x16",
               note: str = "") -> List[RooflineRow]:
    rows = []
    for p in sorted(Path(run_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != mesh or rec.get("note", "") != note:
            continue
        rows.append(analyze_cell(rec))
    order = {a: i for i, a in enumerate(
        ["gemma3-1b", "llama3.2-3b", "stablelm-12b", "qwen3-4b",
         "olmoe-1b-7b", "deepseek-v2-lite", "hymba-1.5b", "qwen2-vl-7b",
         "seamless-m4t-v2", "falcon-mamba-7b"])}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda r: (order.get(r.arch, 99), sorder.get(r.shape, 9)))
    return rows


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/EXEC | roofline frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.status != "ok":
            out.append(f"| {r.arch} | {r.shape} | — | — | — | — | — | — | "
                       f"{r.note} |\n")
            continue
        ratio = r.model_flops / max(r.exec_flops, 1e-30)
        out.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.4f} | {r.memory_s:.4f}"
            f" | {r.collective_s:.4f} | **{r.bottleneck}** | {ratio:.2f} |"
            f" {100 * r.frac_of_roofline:.1f}% | {r.note} |\n")
    return "".join(out)


def csv_rows(rows: List[RooflineRow]) -> str:
    out = ["arch,shape,status,compute_s,memory_s,collective_s,bottleneck,"
           "model_flops,exec_flops,roofline_frac\n"]
    for r in rows:
        out.append(f"{r.arch},{r.shape},{r.status},{r.compute_s:.6g},"
                   f"{r.memory_s:.6g},{r.collective_s:.6g},{r.bottleneck},"
                   f"{r.model_flops:.6g},{r.exec_flops:.6g},"
                   f"{r.frac_of_roofline:.4f}\n")
    return "".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--note", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_cells(args.run_dir, args.mesh, args.note)
    print(csv_rows(rows) if args.csv else markdown_table(rows))


if __name__ == "__main__":
    main()
