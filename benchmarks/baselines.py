"""Baseline-system models for the paper-figure benchmarks (§V-A).

Each baseline estimates one training iteration's time for a varied-length
batch on the paper's cluster (4 nodes x 8 A800, NVLink intra / IB inter),
using the same cost-model primitives as InfiniPipe so comparisons are
apples-to-apples:

* ``infinipipe``   — the real planner + cycle-accurate 1F1B simulator.
* ``seq1f1b``      — uniform splitting into fixed-size chunks + full static
                     checkpointing (the paper's adapted Seq1F1B baseline).
* ``deepspeed_usp``— Ulysses SP across the whole cluster + ZeRO-3: per-layer
                     all-to-alls cross nodes (IB-bound), params gathered per
                     layer per microbatch.
* ``flexsp``       — heterogeneous SP groups: short sequences use intra-node
                     groups, long ones span nodes; workload imbalance across
                     groups adds a straggler factor (§V-B discussion).
* ``megatron``     — TP8 intra-node (per-layer activation all-reduces) +
                     CP ring for attention + PP between nodes.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence

from repro.core import (Chunk, ChunkKind, ClusterSpec, CostModel,
                        PlannerConfig, Slice, plan_batch)

IB_BW = 50e9          # 400 Gb/s InfiniBand per node
NVLINK_BW = 200e9


def _batched(lengths: Sequence[int]) -> Chunk:
    return Chunk(kind=ChunkKind.BATCHED, context=0,
                 slices=tuple(Slice(i, 0, l, True)
                              for i, l in enumerate(lengths)))


def infinipipe_time(cm: CostModel, lengths: List[int]) -> float:
    plan = plan_batch(cm, lengths)
    return plan.est_total_time


def seq1f1b_time(cm: CostModel, lengths: List[int]) -> float:
    plan = plan_batch(cm, lengths,
                      PlannerConfig(uniform_split=True, full_ckpt=True,
                                    fixed_k=cm.cluster.d_p))
    return plan.est_total_time


def deepspeed_usp_time(cm: CostModel, lengths: List[int]) -> float:
    """SP degree = whole cluster; all-to-all crosses IB; ZeRO-3 gathers per
    microbatch. No pipeline (d_p=1)."""
    m = cm.model
    N = cm.cluster.n_devices
    # compute: same total flops, full utilization assumed per microbatch
    comp = sum(cm.t_comp(_batched([l])) for l in lengths) * 3.0  # fwd+bwd
    # comm: ulysses a2a at IB bandwidth per layer, both passes
    toks = sum(lengths)
    e = m.bytes_per_act
    a2a = 2 * (m.d_head_total + m.d_kv) * toks * e / N
    t_comm = m.n_layers * a2a / (IB_BW / 8) * 3.0   # 8 ranks share a NIC
    # ZeRO-3: gather params per layer per microbatch (microbatch ~ per seq)
    n_micro = max(1, len(lengths) // 8)
    zero = 2 * m.param_count() * (N - 1) / N / (IB_BW / 8) * n_micro / N
    return comp + t_comm + zero


def flexsp_time(cm: CostModel, lengths: List[int]) -> float:
    """Heterogeneous SP groups (FlexSP): short seqs intra-node (d_s=8),
    long seqs cluster-wide; groups run concurrently but finish with the
    slowest (workload imbalance)."""
    m = cm.model
    e = m.bytes_per_act
    N = cm.cluster.n_devices
    node = 8
    short = [l for l in lengths if l <= 16384]
    long_ = [l for l in lengths if l > 16384]
    groups = max(1, N // node)

    def grp_time(ls, d_s, bw):
        if not ls:
            return 0.0
        comp = sum(cm.t_comp(_batched([l])) for l in ls) * 3.0 * (N / d_s)
        toks = sum(ls)
        a2a = 2 * (m.d_head_total + m.d_kv) * toks * e / d_s
        return comp + m.n_layers * a2a / bw * 3.0

    # shorts spread over intra-node groups; longs pay IB
    t_short = grp_time(short, node, NVLINK_BW) / groups
    t_long = grp_time(long_, N, IB_BW / 8)
    # imbalance: the slowest group gates the iteration (paper §V-B)
    imbalance = 1.15 if short and long_ else 1.0
    zero = 2 * m.param_count() * (N - 1) / N / (IB_BW / 8) / N * 4
    return (t_short + t_long) * imbalance + zero


def megatron_time(cm: CostModel, lengths: List[int]) -> float:
    """TP=8 (2 all-reduces of activations per layer, NVLink) + CP ring +
    PP inter-node with 1F1B bubbles."""
    m = cm.model
    e = m.bytes_per_act
    toks = sum(lengths)
    comp = sum(cm.t_comp(_batched([l])) for l in lengths) * 3.0
    tp_ar = 2 * 2 * toks * m.d_model * e / 8 / NVLINK_BW * m.n_layers * 3.0
    d_p = 4
    n_micro = max(8, len(lengths) // 16)
    bubble = (d_p - 1) / n_micro
    # full static checkpointing tuned for the longest context (§V-A)
    recompute = comp / 3.0
    return (comp + tp_ar + recompute) * (1 + bubble)


BASELINES = {
    "infinipipe": infinipipe_time,
    "seq1f1b": seq1f1b_time,
    "deepspeed_usp": deepspeed_usp_time,
    "flexsp": flexsp_time,
    "megatron": megatron_time,
}
